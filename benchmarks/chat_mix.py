"""Paper Table 6: chat/QA data-mix trade-off.

Sweeps the UltraChat-analogue : long-context-QA mixture ratio, training an
identical reduced model per ratio, and reports (a) retrieval accuracy on the
QA task and (b) chat-style loss — reproducing the paper's trade-off: more
chat improves chat metrics but degrades needle/fact retrieval.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.packing import packed_loss_weights
from repro.data.needle import NeedleTask, retrieval_accuracy
from repro.data.packing import Example, pack_examples
from repro.data.qa import ChatSampler
from repro.data.vocab import build_vocab
from repro.models.registry import build_model
from repro.train.train_step import init_train_state, make_eval_step, make_train_step

SEQ = 192
MIXES = [(0.0, 1.0), (0.4, 0.6), (0.7, 0.3), (1.0, 0.0)]  # (chat, qa)


def _batch(chat, nt, vocab, rows, rng, chat_frac):
    examples = []
    for _ in range(rows * 3):
        if rng.random() < chat_frac:
            d = chat.dialogue()
            examples.append(Example(d.tokens, d.loss_mask))
        else:
            ex = nt.build(SEQ // 2, num_needles=1, num_retrieve=1)
            examples.append(Example(ex.tokens, ex.loss_mask))
    b = pack_examples(examples, vocab=vocab, seq_len=SEQ, batch_rows=rows)
    w = packed_loss_weights(jnp.asarray(b.segment_ids),
                            jnp.asarray(b.loss_mask),
                            max_segments=b.num_segments + 2)
    return {
        "tokens": b.tokens, "labels": b.labels, "segment_ids": b.segment_ids,
        "positions": b.positions, "loss_weights": np.asarray(w, np.float32),
    }


def run(*, steps: int = 120, rows: int = 4, quick: bool = False) -> list[dict]:
    if quick:
        steps = 50
    cfg = get_reduced("lwm-7b")
    vocab = build_vocab(cfg.vocab_size, 0)
    nt = NeedleTask(vocab, seed=0, key_len=1, val_len=1)
    chat = ChatSampler(vocab, seed=3)
    model = build_model(cfg)
    eval_step = jax.jit(make_eval_step(cfg))

    def chat_eval_loss(params):
        rng = np.random.default_rng(99)
        b = _batch(chat, nt, vocab, rows, rng, chat_frac=1.0)
        _, m = eval_step(params, b)
        return float(m["loss"])

    def needle_eval(params):
        from benchmarks.needle import answer_logprob
        accs, lps = [], []
        for _ in range(4):
            b = nt.batch(rows, SEQ // 2, num_needles=1, num_retrieve=1)
            eb = {
                "tokens": b["tokens"],
                "labels": np.roll(b["tokens"], -1, axis=1),
                "segment_ids": np.ones_like(b["tokens"]),
                "positions": np.tile(np.arange(SEQ // 2, dtype=np.int32),
                                     (rows, 1)),
                "loss_weights": np.roll(b["loss_mask"], -1,
                                        axis=1).astype(np.float32),
            }
            logits, _ = eval_step(params, eb)
            accs.append(retrieval_accuracy(np.asarray(logits, np.float32), b))
            lps.append(answer_logprob(np.asarray(logits, np.float32), b))
        return float(np.mean(accs)), float(np.mean(lps))

    out = []
    for chat_frac, qa_frac in MIXES:
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, learning_rate=1e-3))
        rng = np.random.default_rng(0)
        for _ in range(steps):
            state, _ = step(state, _batch(chat, nt, vocab, rows, rng,
                                          chat_frac))
        acc, lp = needle_eval(state.params)
        out.append({
            "bench": "chat_mix",
            "chat_pct": int(chat_frac * 100), "qa_pct": int(qa_frac * 100),
            "needle_acc": round(acc, 3),
            "needle_logprob": round(lp, 3),
            "chat_loss": round(chat_eval_loss(state.params), 4),
        })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args(argv)
    for row in run(steps=args.steps):
        print(row)


if __name__ == "__main__":
    main()
