"""Fault-tolerant serving under deterministic chaos (paper §5 at scale).

A serving deployment at the paper's scale (million-token contexts, many
hosts) sees preemptions, transient device failures, and numerically-poisoned
requests as routine events, not exceptions. This bench drives the REAL
engine through a seeded ``FaultPlan`` and prices the recovery machinery:

  * measured row — the reduced-LWM paged engine serves a shared-prefix
    workload twice: fault-free baseline vs a chaos run injecting >= 1
    allocator OOM (forcing an eviction + replay), >= 1 failing jitted step
    (absorbed by the capped-backoff retry loop), and one NaN-poisoned
    request. The contract: every non-poisoned request finishes with tokens
    BIT-IDENTICAL to the baseline, the poisoned one retires "error", and
    the recompute tax of replay stays bounded.
  * 1M-context analytic row — the real ``Scheduler`` replays the
    16-users-one-video workload against a bookkeeping ``PagedCachePool``
    with OOMs injected mid-decode. Because the evicted user's replay
    re-matches the still-registered shared video prefix, recovery costs a
    question-tail re-prefill — not a million-token one; the row records
    that overhead ratio and ``tools/check_bench.py`` gates it.

``--dry-run`` (CI smoke) runs a scaled-down analytic replay only — no
model, no compile, no JSON write.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_serve_chaos.json")

NUM_SLOTS = 3
CHUNK = 4
MAX_LEN = 96
BLOCK_SIZE = 8
POISONED_REQ = 4

# Analytic stage: the serve_paged bench's video-QA steady state, now with
# mid-decode allocator pressure.
STAGE_USERS = 16
STAGE_VIDEO_TOKENS = 1 << 20
STAGE_QUESTION_TOKENS = 512
STAGE_MAX_NEW = 256
STAGE_CHUNK = 4096
STAGE_BLOCK = 256


def _requests():
    from repro.serve import Request
    shared = (7 + np.arange(24, dtype=np.int32) * 3) % 900
    fork = np.concatenate([shared[:16],
                           np.arange(500, 510, dtype=np.int32)])
    return [
        Request(prompt=shared, max_new_tokens=6),
        Request(prompt=np.arange(40, 75, dtype=np.int32), max_new_tokens=4),
        Request(prompt=shared.copy(), max_new_tokens=5),
        Request(prompt=fork.astype(np.int32), max_new_tokens=6),
        Request(prompt=np.arange(200, 212, dtype=np.int32),
                max_new_tokens=3),                      # the poisoned one
        Request(prompt=shared.copy(), max_new_tokens=4),
    ]


def _fault_plan():
    from repro.serve import FaultPlan
    # Pinned schedule (seeded plans are tested in tests/test_serve_faults):
    # an OOM once two slots are mid-flight (armed until a victim exists),
    # one failing attempt of step 3, and request 4 poisoned at its first
    # planned row.
    return FaultPlan(oom_steps=(8,), step_errors={3: 1},
                     nan_requests={POISONED_REQ: 0})


def _measured_row() -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.models.registry import build_model
    from repro.serve import (CacheConfig, FaultConfig, ServeConfig,
                             ServeEngine)

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = ServeConfig(
        cache=CacheConfig(max_len=MAX_LEN, paged=True,
                          block_size=BLOCK_SIZE),
        faults=FaultConfig(retry_backoff_s=0.0))

    base_eng = ServeEngine(cfg, params, sc)
    t0 = time.time()
    base = base_eng.serve(_requests(), num_slots=NUM_SLOTS,
                          prefill_chunk=CHUNK)
    base_wall = round(time.time() - t0, 2)

    plan = _fault_plan()
    chaos_eng = ServeEngine(cfg, params, sc, faults=plan)
    t0 = time.time()
    chaos = chaos_eng.serve(_requests(), num_slots=NUM_SLOTS,
                            prefill_chunk=CHUNK)
    chaos_wall = round(time.time() - t0, 2)

    nonpoisoned_match = all(
        np.array_equal(b.tokens, c.tokens) and b.finish_reason == c.finish_reason
        for i, (b, c) in enumerate(zip(base, chaos)) if i != POISONED_REQ)
    useful = max(base_eng.stats["useful_tokens"], 1)
    overhead = chaos_eng.stats["recompute_tokens"] / useful
    return {
        "bench": "serve_chaos",
        "backend": jax.default_backend(),
        "workload": {"requests": len(_requests()), "num_slots": NUM_SLOTS,
                     "prefill_chunk": CHUNK, "max_len": MAX_LEN,
                     "block_size": BLOCK_SIZE, "model": cfg.name,
                     "poisoned_request": POISONED_REQ},
        "fault_plan": plan.describe(),
        "fired": plan.summary(),
        "baseline": {"useful_tokens": base_eng.stats["useful_tokens"],
                     "model_calls": base_eng.stats["model_calls"],
                     "wall_s": base_wall},
        "chaos": {"useful_tokens": chaos_eng.stats["useful_tokens"],
                  "model_calls": chaos_eng.stats["model_calls"],
                  "preemptions": chaos_eng.stats["preemptions"],
                  "preempted_tokens": chaos_eng.stats["preempted_tokens"],
                  "recompute_tokens": chaos_eng.stats["recompute_tokens"],
                  "step_retries": chaos_eng.stats["step_retries"],
                  "poisoned": chaos_eng.stats["poisoned"],
                  "wall_s": chaos_wall},
        "delta": {
            "all_requests_complete": all(r.finish_reason is not None
                                         for r in chaos),
            "nonpoisoned_tokens_match": nonpoisoned_match,
            "poisoned_retired_error":
                chaos[POISONED_REQ].finish_reason == "error",
            "preemptions": int(chaos_eng.stats["preemptions"]),
            "step_retries": int(chaos_eng.stats["step_retries"]),
            "recompute_overhead": round(overhead, 4),
        },
    }


# ---------------------------------------------------------------------------
# 1M-context analytic replay: OOM-preemption recovery cost (no arrays)
# ---------------------------------------------------------------------------

def _stage_replay(*, users, video_tokens, question_tokens, max_new, chunk,
                  block_size, oom_steps) -> dict:
    """Replay the REAL scheduler over the shared-video workload, injecting
    allocator OOMs mid-run; measure how much work preemption recovery
    re-prefills when the shared prefix survives in the registry."""
    from repro.serve import PagedCachePool, Request, Scheduler

    video = ((np.arange(video_tokens, dtype=np.int64) * 2654435761) % 65521
             ).astype(np.int32)
    max_len = video_tokens + question_tokens + max_new
    blocks_per_user = -(-max_len // block_size)
    num_blocks = blocks_per_user + users * (
        -(-(question_tokens + max_new) // block_size) + 4)
    pool = PagedCachePool(users, max_len=max_len, block_size=block_size,
                          num_blocks=num_blocks)
    sched = Scheduler(pool, prefill_chunk=chunk, vocab_size=65536,
                      preemption=True)

    def make_req(u):
        q = (np.arange(question_tokens, dtype=np.int32) + 7919 * (u + 1)) % 65521
        return Request(prompt=np.concatenate([video, q]),
                       max_new_tokens=max_new)

    sched.submit(make_req(0), 0)
    fake = np.ones(users, np.int32)
    pending_ooms = sorted(oom_steps)
    submitted = 1
    useful = 0
    steps = 0
    while sched.has_work:
        sched.retire()
        sched.admit()
        if submitted < users and any(
                st.req_id == 0 and st.cursor >= len(st.req.prompt)
                for st in sched.active.values()):
            for u in range(1, users):
                sched.submit(make_req(u), u)
            submitted = users
            sched.admit()
        if not sched.active:
            continue
        if pending_ooms and steps >= pending_ooms[0]:
            pending_ooms.pop(0)
            sched.inject_oom()
        plan = sched.plan()
        if plan is None:
            continue
        sched.commit(plan, fake)
        useful += int(plan.lengths.sum())
        steps += 1
    sched.retire()
    done = sched.finished
    return dict(useful_tokens=useful, steps=steps,
                completed=sum(r.finish_reason == "length" for r in done),
                requests=len(done),
                preemptions=sched.preemptions,
                preempted_tokens=sched.preempted_tokens,
                recompute_tokens=sched.recompute_tokens,
                preempted_blocks_freed=sched.preempted_blocks_freed)


def _paper_stage_row(*, users=STAGE_USERS, video_tokens=STAGE_VIDEO_TOKENS,
                     question_tokens=STAGE_QUESTION_TOKENS,
                     max_new=STAGE_MAX_NEW, chunk=STAGE_CHUNK,
                     block_size=STAGE_BLOCK, oom_steps=(320, 360)) -> dict:
    # oom_steps land in the decode phase (user 0 prefills solo for
    # video/chunk = 256 steps; injections during a solo phase have no
    # victim and collapse into one armed flag).
    baseline = _stage_replay(users=users, video_tokens=video_tokens,
                             question_tokens=question_tokens,
                             max_new=max_new, chunk=chunk,
                             block_size=block_size, oom_steps=())
    chaos = _stage_replay(users=users, video_tokens=video_tokens,
                          question_tokens=question_tokens, max_new=max_new,
                          chunk=chunk, block_size=block_size,
                          oom_steps=oom_steps)
    overhead = chaos["recompute_tokens"] / max(baseline["useful_tokens"], 1)
    # What recovery WOULD cost without shared-prefix survival: each evicted
    # user re-prefills its full (video + question + generated) context.
    naive = chaos["preemptions"] * (video_tokens + question_tokens)
    return {
        "bench": "serve_chaos",
        "analytic_paper_stage": {
            "workload": {"users": users, "video_tokens": video_tokens,
                         "question_tokens": question_tokens,
                         "max_new": max_new, "prefill_chunk": chunk,
                         "block_size": block_size,
                         "oom_steps": list(oom_steps)},
            "baseline": {k: int(v) for k, v in baseline.items()},
            "chaos": {k: int(v) for k, v in chaos.items()},
            "delta": {
                "all_complete": chaos["completed"] == users,
                "preemptions": int(chaos["preemptions"]),
                "recompute_overhead": round(overhead, 6),
                "naive_replay_tokens": int(naive),
                "replay_tokens_saved_by_prefix":
                    int(naive - chaos["recompute_tokens"]),
            },
        },
    }


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    if dry_run:
        # Scaled-down analytic replay: same recovery code path, CI-sized.
        return [{
            "bench": "serve_chaos", "dry_run": True,
            **_paper_stage_row(users=4, video_tokens=1 << 12,
                               question_tokens=64, max_new=16, chunk=256,
                               block_size=32, oom_steps=(22, 26)),
        }]
    rows = [_measured_row(), _paper_stage_row()]
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
