"""XLA-vs-fused ring-step accounting (paper §3.1 fusion claim).

One RingAttention step = fold the K/V shard that just arrived over the ring
into the running (acc, m, l) carry. Two engines compute it:

  * "xla"   — ``core.blockwise.attend_shard``: einsum loop; the (B,H,Sq,Bk)
              f32 logits tile materializes in memory every block.
  * "fused" — ``kernels.flash_attention.flash_attention_fwd_carry``: one
              Pallas invocation, logits live only in VMEM (lowered here via
              interpret mode, whose HLO has the same tile-level buffers).

Both are lowered and walked with the HLO cost model; the materialized-
logits detector checks buffers >= B*H*Sq*Bk f32 elements. Results (plus the
analytic paper-stage projection from ``launch.fusion``) land in
``BENCH_ring_fused.json`` so future PRs can track the perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_ring_fused.json")

B, H, HKV, D = 1, 4, 2, 64
S_LOCAL = 512
Q_BLOCK = KV_BLOCK = 128


def _mk_inputs():
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, S_LOCAL, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S_LOCAL, HKV, D))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S_LOCAL, HKV, D))
    qpos = jnp.broadcast_to(jnp.arange(S_LOCAL, dtype=jnp.int32), (B, S_LOCAL))
    # the arriving shard holds the *previous* context window (one ring hop)
    kpos = qpos - S_LOCAL // 2
    seg = jnp.ones((B, S_LOCAL), jnp.int32)
    return q, k, v, qpos, kpos, seg


def _xla_step():
    from repro.core import blockwise

    q, k, v, qpos, kpos, seg = _mk_inputs()
    carry = blockwise.init_carry(B, S_LOCAL, H, D)

    def step(q, k, v, carry):
        out = blockwise.attend_shard(
            q, k, v, blockwise.AttnCarry(*carry), q_positions=qpos,
            kv_positions=kpos, q_segment_ids=seg, kv_segment_ids=seg,
            causal=True, kv_block_size=KV_BLOCK, skip_masked_blocks=False)
        return tuple(out)

    return step, (q, k, v, tuple(carry))


def _fused_step():
    from repro.core.attention import NEG_INF
    from repro.kernels import flash_attention as fa

    q, k, v, qpos, kpos, seg = _mk_inputs()
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    carry = (jnp.zeros((B, H, S_LOCAL, D), jnp.float32),
             jnp.full((B, H, S_LOCAL), NEG_INF, jnp.float32),
             jnp.zeros((B, H, S_LOCAL), jnp.float32))

    def step(q, k, v, carry):
        return fa.flash_attention_fwd_carry(
            q, k, v, qpos, kpos, seg, seg, carry, causal=True,
            q_block=Q_BLOCK, kv_block=KV_BLOCK,
            interpret=jax.default_backend() != "tpu")

    return step, (qt, kt, vt, carry)


def _account(step, args, *, iters: int) -> dict:
    from repro.launch import hlo as hlo_mod

    compiled = jax.jit(step).lower(*args).compile()
    text = compiled.as_text()
    cost = hlo_mod.full_cost(text, num_devices=1)
    logits = hlo_mod.materialized_buffer_bytes(
        text, min_elems=B * H * S_LOCAL * KV_BLOCK, dtype="f32")
    out = jax.block_until_ready(compiled(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = compiled(*args)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    return {
        "bytes_accessed": cost.bytes_accessed,
        "flops": cost.flops,
        "logits_buffer_bytes": logits["bytes"],
        "logits_buffer_count": logits["count"],
        "step_ms": round(dt * 1e3, 3),
        "tokens_per_s": round(B * S_LOCAL / dt, 1),
    }


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    from repro.launch import fusion as fusion_mod

    iters = 3 if quick else 10
    xla_step, xla_args = _xla_step()
    fused_step, fused_args = _fused_step()
    if dry_run:
        # CI smoke: shape-level traces of both engines + the analytic
        # models, no compile/execute and no JSON overwrite.
        jax.eval_shape(xla_step, *xla_args)
        jax.eval_shape(fused_step, *fused_args)
        return [{
            "bench": "ring_fused", "dry_run": True,
            "step_bytes_model": fusion_mod.ring_flash_io_bytes(
                s_local=S_LOCAL, ring_devices=1, num_q_heads=H,
                num_kv_heads=HKV, head_dim=D, batch_per_device=B,
                dtype_bytes=4, backward=False),
        }]
    xla = _account(xla_step, xla_args, iters=iters)
    fused = _account(fused_step, fused_args, iters=iters)
    if jax.default_backend() != "tpu":
        # Interpreter HLO walks every tile dynamic-slice as memory traffic;
        # the kernel's true HBM IO is the analytic model (tiles stay in VMEM).
        fused["bytes_accessed_note"] = (
            "interpret-mode overcount; see fused_step_bytes_model")
    fused["step_bytes_model"] = fusion_mod.ring_flash_io_bytes(
        s_local=S_LOCAL, ring_devices=1, num_q_heads=H, num_kv_heads=HKV,
        head_dim=D, batch_per_device=B, dtype_bytes=4, backward=False)

    # Analytic paper-stage projection (LWM-7B-ish heads at 512K over a
    # 16-device ring): XLA bytes measured per step at small scale don't
    # extrapolate, but the kernel IO model does.
    stage = dict(s_local=2 ** 19 // 16, ring_devices=16, num_q_heads=32,
                 num_kv_heads=32, head_dim=128, batch_per_device=8)
    analytic = {
        "stage": stage,
        "ring_fused_bytes": fusion_mod.ring_flash_io_bytes(**stage),
        "single_sweep_bytes": fusion_mod.flash_attention_io_bytes(
            s_local=stage["s_local"], s_kv=2 ** 19,
            num_q_heads=stage["num_q_heads"],
            num_kv_heads=stage["num_kv_heads"],
            head_dim=stage["head_dim"],
            batch_per_device=stage["batch_per_device"]),
    }

    row = {
        "bench": "ring_fused",
        "shape": {"b": B, "h": H, "hkv": HKV, "d": D, "s_local": S_LOCAL,
                  "q_block": Q_BLOCK, "kv_block": KV_BLOCK},
        "backend": jax.default_backend(),
        "xla": xla,
        "fused": fused,
        "delta": {
            # measured XLA step traffic vs the fused kernel's HBM IO model
            "bytes_saved": xla["bytes_accessed"] - fused["step_bytes_model"],
            "logits_buffer_bytes_eliminated":
                xla["logits_buffer_bytes"] - fused["logits_buffer_bytes"],
            "fused_eliminates_logits_buffer":
                xla["logits_buffer_count"] > 0
                and fused["logits_buffer_count"] == 0,
        },
        "analytic_512K_stage": analytic,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(row, f, indent=2)
    return [row]


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
