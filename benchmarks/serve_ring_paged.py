"""Single-device vs ring-sharded paged KV residency (paper §5 at scale).

A single-device paged pool caps the servable context at one device's HBM:
a 1M-token LWM-7B KV cache is ~0.5 TB and fits nowhere. The sequence-
sharded pool (``ShardedPagedCachePool`` + the ring split-K paged decode)
block-stripes every slot's virtual blocks across the ring — device ``s``
owns virtual blocks ``v`` with ``v % D == s`` — so each device holds
~``1/D`` of the resident KV while greedy tokens stay bit-identical (the
ring kernel rotates ``(acc, m, l)`` carries, never K/V or logits).

The unit of accounting is **resident KV bytes per DEVICE** at the run's
peak, sharded vs single-device, at equal token counts.

  * measured row — both engines serve the same shared-prefix workload on
    the reduced LWM over 8 forced host devices (subprocess, so XLA_FLAGS
    lands before jax initializes); the sharded side reports the MEASURED
    peak per-shard block occupancy (max over the 8 allocators, polled at
    every engine step), the single side its peak live-block total; greedy
    tokens must match exactly and peak totals must agree.
  * 1M analytic row — the REAL ``Scheduler`` replays the 16-users-one-
    video workload (1M-token shared prompt, unique question tails) against
    a bookkeeping-only ``ShardedPagedCachePool`` (D=8) and against the
    single-device ``PagedCachePool``; byte totals use full-scale LWM-7B
    cache dims. ``tools/check_bench.py`` gates the committed JSON on
    per-device bytes <= 1.25/D of the single-device residency with
    replayed token parity.

``--dry-run`` (CI smoke) runs a scaled-down analytic replay — no devices,
no compile, no JSON write.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_serve_ring_paged.json")

# Measured small-scale workload mirrors tests/test_serve_ring_paged.py:
# identical-prompt pair + fork-after-16 + distinct, on 2 slots so the fork
# admits after a twin retires and hits the registered prefix.
NUM_SHARDS = 8
NUM_SLOTS = 2
CHUNK = 4
MAX_LEN = 64
BLOCK_SIZE = 8

# Paper-stage analytic workload (same service as BENCH_serve_paged's 1M
# row): one hour-long video chatted over by many users.
STAGE_USERS = 16
STAGE_VIDEO_TOKENS = 1 << 20
STAGE_QUESTION_TOKENS = 512
STAGE_MAX_NEW = 256
STAGE_CHUNK = 4096
STAGE_BLOCK = 256


def _bytes_per_token(cfg) -> int:
    """Per-token KV footprint across every attention layer (k + v)."""
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    return (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
            * dtype_bytes)


# ---------------------------------------------------------------------------
# Measured run (real engines, reduced model, 8 forced host devices)
# ---------------------------------------------------------------------------

_MEASURED_SCRIPT = textwrap.dedent("""
    import json
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={d}"
    import jax, numpy as np
    from repro.core import jax_compat as jc
    from repro.configs import get_reduced
    from repro.models.context import RuntimeCtx
    from repro.models.registry import build_model
    from repro.serve import CacheConfig, Request, ServeConfig, ServeEngine
    import repro.serve.pool as pool_mod

    # Instrument the sharded pool: the engine polls pool.live_blocks every
    # step for its peak stat — piggyback a per-shard peak on the same poll.
    peak_shard = [0]
    _orig = pool_mod.ShardedPagedCachePool.live_blocks.fget
    def _live(self):
        per = [self.blocks_per_shard - a.num_free for a in self.allocators]
        peak_shard[0] = max(peak_shard[0], max(per))
        return _orig(self)
    pool_mod.ShardedPagedCachePool.live_blocks = property(_live)

    cfg = get_reduced("lwm-7b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    mesh = jc.make_mesh(({d},), ("seq",))
    ctx = RuntimeCtx(mesh=mesh, rules={{"seq": "seq"}}, ring_axis="seq",
                     decode_ring=True)

    p = np.arange(10, 31, dtype=np.int32)
    reqs = [Request(prompt=p, max_new_tokens=4),
            Request(prompt=p.copy(), max_new_tokens=5),
            Request(prompt=np.concatenate(
                [p[:16], np.arange(70, 75)]).astype(np.int32),
                    max_new_tokens=4),
            Request(prompt=np.arange(40, 49, dtype=np.int32),
                    max_new_tokens=3)]

    def run(ring):
        sc = ServeConfig(cache=CacheConfig(
            max_len={max_len}, paged=True, block_size={bs}))
        eng = ServeEngine(cfg, params, sc,
                          ctx=ctx if ring else RuntimeCtx())
        out = eng.serve(list(reqs), num_slots={slots}, prefill_chunk={chunk})
        return [r.tokens for r in out], eng.stats

    single, st1 = run(False)
    sharded, st8 = run(True)
    print(json.dumps({{
        "tokens_match": all(np.array_equal(a, b)
                            for a, b in zip(single, sharded)),
        "single_peak_live_blocks": int(st1["peak_live_blocks"]),
        "sharded_peak_live_blocks": int(st8["peak_live_blocks"]),
        "sharded_peak_blocks_per_device": int(peak_shard[0]),
        "prefix_hit_tokens": int(st8["prefix_hit_tokens"]),
    }}))
""")


def _measured_row() -> dict:
    from repro.configs import get_reduced

    cfg = get_reduced("lwm-7b")
    bpt = _bytes_per_token(cfg)
    src = os.path.join(HERE, "..", "src")
    env = dict(os.environ, PYTHONPATH=os.path.abspath(src))
    env.pop("XLA_FLAGS", None)
    code = _MEASURED_SCRIPT.format(d=NUM_SHARDS, max_len=MAX_LEN,
                                   bs=BLOCK_SIZE, slots=NUM_SLOTS,
                                   chunk=CHUNK)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"measured subprocess failed:\n{r.stderr}")
    m = json.loads(r.stdout.strip().splitlines()[-1])

    single_bytes = m["single_peak_live_blocks"] * BLOCK_SIZE * bpt
    per_dev_bytes = m["sharded_peak_blocks_per_device"] * BLOCK_SIZE * bpt
    return {
        "bench": "serve_ring_paged",
        "workload": {"requests": 4, "num_slots": NUM_SLOTS,
                     "num_shards": NUM_SHARDS, "prefill_chunk": CHUNK,
                     "max_len": MAX_LEN, "block_size": BLOCK_SIZE,
                     "model": cfg.name, "kv_bytes_per_token": bpt},
        "single_device": {
            "resident_kv_bytes_per_device": single_bytes,
            "peak_live_blocks": m["single_peak_live_blocks"]},
        "sharded": {
            "resident_kv_bytes_per_device": per_dev_bytes,
            "peak_live_blocks": m["sharded_peak_live_blocks"],
            "peak_blocks_per_device": m["sharded_peak_blocks_per_device"],
            "prefix_hit_tokens": m["prefix_hit_tokens"]},
        "delta": {
            "tokens_match": bool(m["tokens_match"]),
            "peak_blocks_match": (m["single_peak_live_blocks"]
                                  == m["sharded_peak_live_blocks"]),
            "sharded_strictly_fewer_bytes_per_device":
                per_dev_bytes < single_bytes,
            "per_device_ratio": round(per_dev_bytes / max(single_bytes, 1),
                                      4),
        },
    }


# ---------------------------------------------------------------------------
# 1M-context analytic replay (real scheduler + sharded allocators, no arrays)
# ---------------------------------------------------------------------------

def _replay(pool, *, users, video_tokens, question_tokens, max_new, chunk,
            poll=None) -> dict:
    """Replay the REAL scheduler over the shared-video workload against a
    bookkeeping-only pool; ``poll(pool)`` samples extra occupancy stats at
    every committed step."""
    from repro.serve import Request, Scheduler

    video = ((np.arange(video_tokens, dtype=np.int64) * 2654435761) % 65521
             ).astype(np.int32)
    sched = Scheduler(pool, prefill_chunk=chunk, vocab_size=65536)

    def make_req(u):
        q = (np.arange(question_tokens, dtype=np.int32)
             + 7919 * (u + 1)) % 65521
        return Request(prompt=np.concatenate([video, q]),
                       max_new_tokens=max_new)

    sched.submit(make_req(0), 0)
    fake = np.ones(users, np.int32)
    submitted = 1
    peak_blocks = 0
    peak_active = 0
    useful = 0
    while sched.has_work:
        sched.retire()
        sched.admit()
        if submitted < users and any(
                st.req_id == 0 and st.cursor >= len(st.req.prompt)
                for st in sched.active.values()):
            for u in range(1, users):
                sched.submit(make_req(u), u)
            submitted = users
            sched.admit()
        if not sched.active:
            break
        plan = sched.plan()
        if plan is None:
            continue
        sched.commit(plan, fake)
        useful += int(plan.lengths.sum())
        peak_blocks = max(peak_blocks, pool.live_blocks)
        peak_active = max(peak_active, len(sched.active))
        if poll is not None:
            poll(pool)
    prefix_hits = sum(st.prefix_hit for st in sched.finished)
    return dict(peak_live_blocks=peak_blocks, peak_concurrent=peak_active,
                useful_tokens=useful, prefix_hit_tokens=prefix_hits)


def _paper_stage_row(*, users=STAGE_USERS, video_tokens=STAGE_VIDEO_TOKENS,
                     question_tokens=STAGE_QUESTION_TOKENS,
                     max_new=STAGE_MAX_NEW, chunk=STAGE_CHUNK,
                     block_size=STAGE_BLOCK, num_shards=NUM_SHARDS) -> dict:
    from repro.configs import get_config
    from repro.serve import PagedCachePool
    from repro.serve.pool import ShardedPagedCachePool

    cfg = get_config("lwm-7b")           # full-scale cache dims
    bpt = _bytes_per_token(cfg)
    max_len = video_tokens + question_tokens + max_new
    blocks_per_user = -(-max_len // block_size)
    num_blocks = blocks_per_user + users * (
        -(-(question_tokens + max_new) // block_size) + 4)
    wl = dict(users=users, video_tokens=video_tokens,
              question_tokens=question_tokens, max_new=max_new, chunk=chunk)

    single = _replay(
        PagedCachePool(users, max_len=max_len, block_size=block_size,
                       num_blocks=num_blocks), **wl)

    peak_shard = [0]

    def poll(pool):
        peak_shard[0] = max(peak_shard[0], max(
            pool.blocks_per_shard - a.num_free for a in pool.allocators))

    sharded = _replay(
        ShardedPagedCachePool(users, num_shards=num_shards, max_len=max_len,
                              block_size=block_size, num_blocks=num_blocks),
        **wl, poll=poll)

    single_tokens = single["useful_tokens"] + single["prefix_hit_tokens"]
    sharded_tokens = sharded["useful_tokens"] + sharded["prefix_hit_tokens"]
    single_bytes = single["peak_live_blocks"] * block_size * bpt
    per_dev_bytes = peak_shard[0] * block_size * bpt
    ratio = per_dev_bytes / max(single_bytes, 1)
    return {
        "bench": "serve_ring_paged",
        "analytic_paper_stage": {
            "workload": {"users": users, "video_tokens": video_tokens,
                         "question_tokens": question_tokens,
                         "max_new": max_new, "prefill_chunk": chunk,
                         "block_size": block_size,
                         "num_shards": num_shards, "model": cfg.name,
                         "kv_bytes_per_token": bpt},
            "single_device": {
                "resident_kv_bytes_per_device": single_bytes,
                "peak_live_blocks": int(single["peak_live_blocks"]),
                "useful_tokens": int(single_tokens)},
            "sharded": {
                "resident_kv_bytes_per_device": per_dev_bytes,
                "peak_live_blocks": int(sharded["peak_live_blocks"]),
                "peak_blocks_per_device": int(peak_shard[0]),
                "useful_tokens": int(sharded_tokens)},
            "delta": {
                "tokens_match": sharded_tokens == single_tokens,
                "sharded_strictly_fewer_bytes_per_device":
                    per_dev_bytes < single_bytes,
                "per_device_ratio": round(ratio, 4),
                # ideal is 1/D; striping granularity must stay within 25%
                "within_125pct_of_ideal": ratio <= 1.25 / num_shards,
            },
        },
    }


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    if dry_run:
        # Scaled-down replay: same scheduler + sharded-allocator code
        # path, CI-smoke sized (seconds, no devices).
        return [{
            "bench": "serve_ring_paged", "dry_run": True,
            **_paper_stage_row(users=4, video_tokens=1 << 12,
                               question_tokens=64, max_new=16, chunk=256,
                               block_size=32, num_shards=4),
        }]
    rows = [_measured_row(), _paper_stage_row()]
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
