"""Paper Tables 1/11 + Appendix F: the progressive context-extension stage
LADDER as a runtime benchmark.

Three measurements, all landing in ``BENCH_context_stages.json`` (gated
fail-closed by ``tools/check_bench.py``):

  * measured stage ladder — the reduced Table 11 ladder runs through the
    PR 4 trainer with a real host-mesh sharding policy per stage (donated
    jit step, policy-selected layout); per-stage loss trajectory and tok/s.
  * measured accumulation parity — the same token budget trained as
    (rows=2, accum=1) vs (rows=1, accum=2): the lax.scan gradient
    accumulator must consume exactly the same number of tokens (the paper's
    4M-token batches only exist through accumulation), with the loss
    trajectory agreeing to microbatch-normalization noise.
  * analytic stage-boundary re-layout — the FULL-SCALE ladder (32K -> 1M on
    a 256-device pod) with Appendix-F-style per-stage mesh splits (tensor
    parallelism widens as seq grows and the batch no longer fills the data
    axis). At each boundary, ``sharding.reshard_plan`` accounts the bytes a
    spec-diff reshard moves per device vs naively gathering the TrainState
    replicated — the quantity the trainer's ``reshard_state`` boundary hop
    is designed to win.
  * analytic 2D-crossover rows — at every sequence-parallel full-scale
    stage, ``sharding.seq_parallel_comm_bytes`` prices the pure ring vs
    the ring x head-parallel (ring2d) layout and records which policy the
    crossover picks; >= 256K stages must pick ring2d.
  * measured ring2d grid — a (2,2,2) DxHxM host mesh (8-device subprocess)
    trains one short stage under every (policy in {ring, ring2d},
    remat_policy in {none, nothing_saveable}) pair: tok/s, loss
    trajectory (ring vs ring2d parity to fold-order tolerance, remat
    bitwise), token parity, and the compiled step's peak temp bytes
    (``compiled.memory_analysis()`` — the CPU-portable stand-in for
    device memory stats) showing remat cutting peak live bytes.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.configs import get_config, get_reduced
from repro.data.pipeline import LWM_1K, LWM_8K, TEXT_STAGE
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.train import StageSpec, Trainer
from repro.train.sharding import (policy_for_stage, reshard_plan,
                                  seq_parallel_comm_bytes)

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_PATH = os.path.join(HERE, "..", "BENCH_context_stages.json")
SRC = os.path.join(HERE, "..", "src")

# Reduced ladder mirroring Table 11 (seq scaled /256, theta schedule kept).
TEXT_LADDER = [
    ("32K", 128, 1e6), ("128K", 512, 1e7), ("256K", 1024, 1e7),
]
VISION_LADDER = [
    ("1K", 256, 5e7), ("8K", 512, 5e7),
]

# Appendix-F-style per-stage (data, heads, model) splits of one 256-device
# pod: the 4M-token batch fills the data axes at short contexts; as seq
# doubles the rows shrink, the split shifts toward tensor/sequence
# parallelism, and once sequence parallelism is wide (>= 256K) a "heads"
# axis carves the ring in two dimensions (ring x head-parallel a2a).
FULL_SEQS = [32_768, 131_072, 262_144, 524_288, 1_048_576]
FULL_SPLITS = {32_768: (64, 1, 4), 131_072: (32, 1, 8),
               262_144: (32, 2, 4), 524_288: (16, 4, 4),
               1_048_576: (8, 8, 4)}
TOKENS_PER_BATCH = 4_194_304


class _MeshShape:
    """Duck-typed mesh (shape mapping only) — enough for spec/byte logic,
    no devices needed for the full-scale analytic rows."""

    def __init__(self, data: int, model: int, heads: int = 1):
        self.shape = {"data": data, "model": model}
        if heads > 1:
            self.shape = {"data": data, "heads": heads, "model": model}


def _policy_name(pol) -> str:
    if pol.head_axis is not None:
        return "ring2d"
    return "ring" if pol.ring_axis is not None else "fsdp"


def _stages(vision: bool, steps: int) -> list[StageSpec]:
    ladder = VISION_LADDER if vision else TEXT_LADDER
    out = []
    for name, seq, theta in ladder:
        mix = (LWM_1K if vision and seq <= 256 else
               LWM_8K if vision else TEXT_STAGE)
        out.append(StageSpec(
            name=("vis-" if vision else "text-") + name, seq_len=seq,
            rope_theta=theta, steps=steps, batch_rows=2, mixture=mix,
            lr=3e-4, schedule="cosine" if vision else "constant",
            warmup=max(steps // 10, 1)))
    return out


def _measured_ladder(*, vision: bool, steps: int) -> list[dict]:
    mesh = make_host_mesh((1, 1), ("data", "model"))
    tr = Trainer(get_reduced("lwm-7b"), _stages(vision, steps), seed=0,
                 mesh=mesh, log_every=max(steps // 3, 1))
    tr.run()
    rows = []
    for h in tr.history:
        rows.append({
            "bench": "context_stages",
            "mode": "measured",
            "stage": h["stage"], "seq_len": h["seq_len"],
            "rope_theta": h["rope_theta"],
            "policy": h["policy"], "accum_steps": h["accum_steps"],
            "first_loss": round(h["first_loss"], 4),
            "final_loss": round(h["final_loss"], 4),
            "tokens": h["tokens"],
            "tok_per_s": round(h["tokens"] / h["wall_s"], 1),
        })
    return rows


def _accum_parity(*, steps: int) -> dict:
    """Same token budget, accumulation off vs on (rows x accum constant)."""
    seq, theta = 128, 1e6
    specs = {
        "off": StageSpec("acc-off", seq, theta, steps, batch_rows=2),
        "on": StageSpec("acc-on", seq, theta, steps, batch_rows=1,
                        accum_steps=2),
    }
    mesh = make_host_mesh((1, 1), ("data", "model"))
    out = {}
    for tag, spec in specs.items():
        tr = Trainer(get_reduced("lwm-7b"), [spec], seed=0, mesh=mesh,
                     log_every=10 ** 9, log_fn=lambda *_: None)
        h = tr.run()[0]
        out[tag] = {"tokens": h["tokens"], "final_loss": h["final_loss"],
                    "tok_per_s": round(h["tokens"] / h["wall_s"], 1),
                    "accum_steps": h["accum_steps"]}
    delta = abs(out["on"]["final_loss"] - out["off"]["final_loss"])
    return {
        "bench": "context_stages",
        "accum_parity": {
            **{f"{k}_{tag}": v for tag, d in out.items()
               for k, v in d.items()},
            "tokens_match": out["on"]["tokens"] == out["off"]["tokens"],
            "final_loss_delta": round(delta, 4),
        },
    }


def _full_scale_policies(cfg):
    policies = {}
    for seq in FULL_SEQS:
        data, heads, tp = FULL_SPLITS[seq]
        rows = TOKENS_PER_BATCH // seq
        policies[seq] = (policy_for_stage(
            cfg, _MeshShape(data, tp, heads), seq, rows),
            (data, heads, tp), rows)
    return policies


def _boundary_rows() -> list[dict]:
    """Full-scale Appendix-F ladder: bytes moved at every stage boundary."""
    cfg = get_config("lwm-7b")
    model = build_model(cfg)
    policies = _full_scale_policies(cfg)
    rows_out = []
    for prev, nxt in zip(FULL_SEQS, FULL_SEQS[1:]):
        src, src_split, src_rows = policies[prev]
        dst, dst_split, dst_rows = policies[nxt]
        plan = reshard_plan(model, src, dst)
        rows_out.append({
            "bench": "context_stages",
            "analytic_boundary": {
                "from_seq": prev, "to_seq": nxt,
                "from_mesh": {"data": src_split[0], "heads": src_split[1],
                              "model": src_split[2]},
                "to_mesh": {"data": dst_split[0], "heads": dst_split[1],
                            "model": dst_split[2]},
                "from_policy": _policy_name(src),
                "to_policy": _policy_name(dst),
                "from_batch_rows": src_rows, "to_batch_rows": dst_rows,
                **plan,
                "reshard_beats_replicate":
                    plan["reshard_bytes_per_device"]
                    < plan["replicate_bytes_per_device"],
            },
        })
    return rows_out


def _crossover_rows() -> list[dict]:
    """Full-scale analytic ring-vs-ring2d comm pricing per SP stage."""
    cfg = get_config("lwm-7b")
    rows_out = []
    for seq, (pol, (data, heads, tp), rows) in _full_scale_policies(
            cfg).items():
        name = _policy_name(pol)
        if name == "fsdp":
            continue
        b = seq_parallel_comm_bytes(cfg, seq, rows, ring_size=data,
                                    head_size=heads)
        rows_out.append({
            "bench": "context_stages",
            "analytic_crossover": {
                "seq_len": seq, "batch_rows": rows,
                "mesh": {"data": data, "heads": heads, "model": tp},
                "chosen_policy": name,
                "ring_bytes_per_device": b["ring_bytes_per_device"],
                "ring2d_bytes_per_device": b["ring2d_bytes_per_device"],
                "ring2d_a2a_bytes_per_device":
                    b["ring2d_a2a_bytes_per_device"],
                "ring2d_beats_ring": b["ring2d_bytes_per_device"]
                                     < b["ring_bytes_per_device"],
            },
        })
    return rows_out


_GRID_SCRIPT = r"""
import json, os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.train import StageSpec, Trainer
from repro.train.sharding import policy_for_stage, state_shardings
from repro.train.train_step import (LossConfig, init_train_state,
                                    make_train_step)

STEPS = int(sys.argv[1])
cfg = get_reduced("lwm-7b")
mesh = make_host_mesh((2, 2, 2), ("data", "heads", "model"))
model = build_model(cfg)

# peak-live-bytes probe at a longer seq (where activations dominate):
# compiled.memory_analysis() temp bytes — CPU-portable stand-in for device
# memory stats (devices report none on the host platform).
S_PROBE = 1024
state_sh = jax.eval_shape(lambda r: init_train_state(model, r),
                          jax.random.PRNGKey(0))
probe_batch = {
    "tokens": jax.ShapeDtypeStruct((1, S_PROBE), jnp.int32),
    "labels": jax.ShapeDtypeStruct((1, S_PROBE), jnp.int32),
    "segment_ids": jax.ShapeDtypeStruct((1, S_PROBE), jnp.int32),
    "positions": jax.ShapeDtypeStruct((1, S_PROBE), jnp.int32),
    "loss_weights": jax.ShapeDtypeStruct((1, S_PROBE), jnp.float32),
}

rows = []
for pol_name in ("ring", "ring2d"):
    for rp in (None, "nothing_saveable"):
        pol = policy_for_stage(cfg, mesh, S_PROBE, 1, force=pol_name,
                               remat_policy=rp)
        step = make_train_step(cfg, ctx=pol.ctx(), learning_rate=1e-3,
                               lcfg=LossConfig())
        compiled = jax.jit(
            step,
            in_shardings=(state_shardings(model, pol),
                          pol.batch_sharding(probe_batch, seq_sharded=True)),
            out_shardings=(state_shardings(model, pol), None),
        ).lower(state_sh, probe_batch).compile()
        temp = compiled.memory_analysis().temp_size_in_bytes

        st = StageSpec(name=f"{pol_name}-{rp or 'none'}", seq_len=256,
                       rope_theta=1e6, steps=STEPS, batch_rows=1, lr=3e-4,
                       warmup=1, remat_policy=rp, policy=pol_name)
        tr = Trainer(cfg, [st], seed=0, mesh=mesh, log_every=10 ** 9,
                     log_fn=lambda *_: None)
        h = tr.run()[0]
        rows.append({
            "policy": pol_name, "remat_policy": rp or "none",
            "seq_len": 256, "steps": STEPS,
            "losses": [round(x, 6) for x in h["losses"]],
            "final_loss": round(h["final_loss"], 6),
            "tokens": h["tokens"],
            "tok_per_s": round(h["tokens"] / h["wall_s"], 1),
            "peak_temp_bytes_probe": int(temp),
            "probe": {"kind": "memory_analysis.temp_size_in_bytes",
                      "seq_len": S_PROBE},
        })

# single-step parity from IDENTICAL params + microbatch (optimizer-free
# comparison: multi-step trajectories drift chaotically at smoke scale as
# fold-order noise compounds through updates — one step isolates the
# attention layouts themselves).
S_PAR = 256
state = init_train_state(model, jax.random.PRNGKey(0))
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (1, S_PAR), 0,
                                  cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (1, S_PAR), 0,
                                  cfg.vocab_size),
    "segment_ids": jnp.ones((1, S_PAR), jnp.int32),
    "positions": jnp.broadcast_to(jnp.arange(S_PAR, dtype=jnp.int32),
                                  (1, S_PAR)),
    "loss_weights": jnp.ones((1, S_PAR), jnp.float32),
}
par = {}
for pol_name in ("ring", "ring2d"):
    pol = policy_for_stage(cfg, mesh, S_PAR, 1, force=pol_name)
    step = make_train_step(cfg, ctx=pol.ctx(), learning_rate=1e-3,
                           lcfg=LossConfig())
    sh = state_shardings(model, pol)
    _, m = jax.jit(step, in_shardings=(sh, pol.batch_sharding(
        batch, seq_sharded=True)), out_shardings=(sh, None))(
        jax.device_put(state, sh), batch)
    par[pol_name] = {"loss": float(m["loss"]),
                     "grad_norm": float(m["grad_norm"])}
print("GRID_JSON:" + json.dumps({"grid": rows, "step_parity": par}))
"""


def _ring2d_grid(*, steps: int) -> list[dict]:
    """Measured (policy x remat) grid on an 8-device (2,2,2) subprocess."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _GRID_SCRIPT, str(steps)],
                       env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"ring2d grid subprocess failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    payload = [ln for ln in r.stdout.splitlines()
               if ln.startswith("GRID_JSON:")][0]
    out = json.loads(payload[len("GRID_JSON:"):])
    grid, par = out["grid"], out["step_parity"]

    by = {(g["policy"], g["remat_policy"]): g for g in grid}
    # Parity is judged on ONE step from identical params/batch (the
    # step_parity probe): multi-step smoke trajectories optimize
    # independently, so fold-order noise compounds through updates and the
    # final losses drift apart without any layout bug. The trajectory delta
    # is kept as an informational field only.
    loss_delta = abs(par["ring"]["loss"] - par["ring2d"]["loss"])
    grad_delta = abs(par["ring"]["grad_norm"] - par["ring2d"]["grad_norm"]
                     ) / max(par["ring"]["grad_norm"], 1e-9)
    ring, ring2d = by[("ring", "none")], by[("ring2d", "none")]
    traj_delta = max(abs(a - b) for a, b in
                     zip(ring["losses"], ring2d["losses"]))
    remat_loss_delta = max(
        abs(a - b) for pol in ("ring", "ring2d")
        for a, b in zip(by[(pol, "none")]["losses"],
                        by[(pol, "nothing_saveable")]["losses"]))
    rows = [{"bench": "context_stages", "mode": "measured_2d", **g}
            for g in grid]
    rows.append({
        "bench": "context_stages",
        "ring2d_parity": {
            "tokens_match": len({g["tokens"] for g in grid}) == 1,
            "loss_delta_ring_vs_ring2d": round(loss_delta, 6),
            "grad_norm_rel_delta": round(grad_delta, 6),
            "step_parity": par,
            "trajectory_delta_info": round(traj_delta, 6),
            "loss_delta_remat": round(remat_loss_delta, 6),
            "remat_cuts_peak_bytes": {
                pol: by[(pol, "nothing_saveable")]["peak_temp_bytes_probe"]
                     < by[(pol, "none")]["peak_temp_bytes_probe"]
                for pol in ("ring", "ring2d")
            },
        },
    })
    return rows


def run(*, vision: bool = False, steps: int = 20, quick: bool = False,
        dry_run: bool = False) -> list[dict]:
    if quick:
        steps = 6
    if dry_run:
        # Setup validation in seconds: the analytic boundary plans build
        # (full-scale specs + byte model) and the accum step traces at
        # shape level, without training or writing JSON.
        import jax
        import jax.numpy as jnp

        from repro.train.train_step import init_train_state, make_train_step

        rows = _boundary_rows() + _crossover_rows()
        cfg = get_reduced("lwm-7b")
        model = build_model(cfg)
        state = jax.eval_shape(
            lambda r: init_train_state(model, r), jax.random.PRNGKey(0))
        a, b, s = 2, 1, 64
        batch = {
            "tokens": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "segment_ids": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "positions": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "loss_weights": jax.ShapeDtypeStruct((a, b, s), jnp.float32),
        }
        jax.eval_shape(make_train_step(cfg, accum_steps=a), state, batch)
        return rows + [{"bench": "context_stages", "dry_run": True}]

    rows = _measured_ladder(vision=vision, steps=steps)
    if not vision:
        rows.append(_accum_parity(steps=steps))
        rows.extend(_boundary_rows())
        rows.extend(_crossover_rows())
        rows.extend(_ring2d_grid(steps=max(steps // 3, 3)))
        with open(OUT_PATH, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vision", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(vision=args.vision, steps=args.steps,
                   dry_run=args.dry_run):
        print(json.dumps(row))


if __name__ == "__main__":
    main()
