"""Paper Tables 1/11 (+7/13 with --vision): progressive context-extension
stage sweep at reduced scale.

Trains the LWM model through the paper's stage ladder (seq lengths scaled
down for CPU) and reports per-stage loss trajectory and throughput —
demonstrating the paper's central training recipe: each stage initializes
from the previous, RoPE theta grows with the context window, and loss keeps
improving as context grows.
"""
from __future__ import annotations

import argparse

from repro.configs import get_reduced
from repro.data.pipeline import LWM_1K, LWM_8K, TEXT_STAGE
from repro.train import StageSpec, Trainer

# Reduced ladder mirroring Table 11 (seq scaled /256, theta schedule kept).
TEXT_LADDER = [
    ("32K", 128, 1e6), ("128K", 512, 1e7), ("256K", 1024, 1e7),
]
VISION_LADDER = [
    ("1K", 256, 5e7), ("8K", 512, 5e7),
]


def run(*, vision: bool = False, steps: int = 20, rows: int = 2,
        quick: bool = False) -> list[dict]:
    if quick:
        steps = 6
    cfg = get_reduced("lwm-7b")
    ladder = VISION_LADDER if vision else TEXT_LADDER
    stages = []
    for name, seq, theta in ladder:
        mix = (LWM_1K if vision and seq <= 256 else
               LWM_8K if vision else TEXT_STAGE)
        stages.append(StageSpec(
            name=("vis-" if vision else "text-") + name, seq_len=seq,
            rope_theta=theta, steps=steps, batch_rows=rows, mixture=mix,
            lr=3e-4, schedule="cosine" if vision else "constant",
            warmup=max(steps // 10, 1)))
    tr = Trainer(cfg, stages, seed=0, log_every=max(steps // 3, 1))
    tr.run()
    rows_out = []
    for h in tr.history:
        rows_out.append({
            "bench": "context_stages",
            "stage": h["stage"], "seq_len": h["seq_len"],
            "rope_theta": h["rope_theta"],
            "first_loss": round(h["first_loss"], 4),
            "final_loss": round(h["final_loss"], 4),
            "tok_per_s": round(h["tokens"] / h["wall_s"], 1),
        })
    return rows_out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vision", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args(argv)
    for row in run(vision=args.vision, steps=args.steps):
        print(row)


if __name__ == "__main__":
    main()
