"""Paper Tables 1/11 + Appendix F: the progressive context-extension stage
LADDER as a runtime benchmark.

Three measurements, all landing in ``BENCH_context_stages.json`` (gated
fail-closed by ``tools/check_bench.py``):

  * measured stage ladder — the reduced Table 11 ladder runs through the
    PR 4 trainer with a real host-mesh sharding policy per stage (donated
    jit step, policy-selected layout); per-stage loss trajectory and tok/s.
  * measured accumulation parity — the same token budget trained as
    (rows=2, accum=1) vs (rows=1, accum=2): the lax.scan gradient
    accumulator must consume exactly the same number of tokens (the paper's
    4M-token batches only exist through accumulation), with the loss
    trajectory agreeing to microbatch-normalization noise.
  * analytic stage-boundary re-layout — the FULL-SCALE ladder (32K -> 1M on
    a 256-device pod) with Appendix-F-style per-stage mesh splits (tensor
    parallelism widens as seq grows and the batch no longer fills the data
    axis). At each boundary, ``sharding.reshard_plan`` accounts the bytes a
    spec-diff reshard moves per device vs naively gathering the TrainState
    replicated — the quantity the trainer's ``reshard_state`` boundary hop
    is designed to win.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import get_config, get_reduced
from repro.data.pipeline import LWM_1K, LWM_8K, TEXT_STAGE
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.train import StageSpec, Trainer
from repro.train.sharding import policy_for_stage, reshard_plan

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_PATH = os.path.join(HERE, "..", "BENCH_context_stages.json")

# Reduced ladder mirroring Table 11 (seq scaled /256, theta schedule kept).
TEXT_LADDER = [
    ("32K", 128, 1e6), ("128K", 512, 1e7), ("256K", 1024, 1e7),
]
VISION_LADDER = [
    ("1K", 256, 5e7), ("8K", 512, 5e7),
]

# Appendix-F-style per-stage (data, model) splits of one 256-device pod:
# the 4M-token batch fills the data axis at short contexts; as seq doubles
# the rows shrink and the split shifts toward tensor/sequence parallelism.
FULL_SEQS = [32_768, 131_072, 262_144, 524_288, 1_048_576]
FULL_SPLITS = {32_768: (64, 4), 131_072: (32, 8), 262_144: (16, 16),
               524_288: (16, 16), 1_048_576: (8, 32)}
TOKENS_PER_BATCH = 4_194_304


class _MeshShape:
    """Duck-typed mesh (shape mapping only) — enough for spec/byte logic,
    no devices needed for the full-scale analytic rows."""

    def __init__(self, data: int, model: int):
        self.shape = {"data": data, "model": model}


def _stages(vision: bool, steps: int) -> list[StageSpec]:
    ladder = VISION_LADDER if vision else TEXT_LADDER
    out = []
    for name, seq, theta in ladder:
        mix = (LWM_1K if vision and seq <= 256 else
               LWM_8K if vision else TEXT_STAGE)
        out.append(StageSpec(
            name=("vis-" if vision else "text-") + name, seq_len=seq,
            rope_theta=theta, steps=steps, batch_rows=2, mixture=mix,
            lr=3e-4, schedule="cosine" if vision else "constant",
            warmup=max(steps // 10, 1)))
    return out


def _measured_ladder(*, vision: bool, steps: int) -> list[dict]:
    mesh = make_host_mesh((1, 1), ("data", "model"))
    tr = Trainer(get_reduced("lwm-7b"), _stages(vision, steps), seed=0,
                 mesh=mesh, log_every=max(steps // 3, 1))
    tr.run()
    rows = []
    for h in tr.history:
        rows.append({
            "bench": "context_stages",
            "mode": "measured",
            "stage": h["stage"], "seq_len": h["seq_len"],
            "rope_theta": h["rope_theta"],
            "policy": h["policy"], "accum_steps": h["accum_steps"],
            "first_loss": round(h["first_loss"], 4),
            "final_loss": round(h["final_loss"], 4),
            "tokens": h["tokens"],
            "tok_per_s": round(h["tokens"] / h["wall_s"], 1),
        })
    return rows


def _accum_parity(*, steps: int) -> dict:
    """Same token budget, accumulation off vs on (rows x accum constant)."""
    seq, theta = 128, 1e6
    specs = {
        "off": StageSpec("acc-off", seq, theta, steps, batch_rows=2),
        "on": StageSpec("acc-on", seq, theta, steps, batch_rows=1,
                        accum_steps=2),
    }
    mesh = make_host_mesh((1, 1), ("data", "model"))
    out = {}
    for tag, spec in specs.items():
        tr = Trainer(get_reduced("lwm-7b"), [spec], seed=0, mesh=mesh,
                     log_every=10 ** 9, log_fn=lambda *_: None)
        h = tr.run()[0]
        out[tag] = {"tokens": h["tokens"], "final_loss": h["final_loss"],
                    "tok_per_s": round(h["tokens"] / h["wall_s"], 1),
                    "accum_steps": h["accum_steps"]}
    delta = abs(out["on"]["final_loss"] - out["off"]["final_loss"])
    return {
        "bench": "context_stages",
        "accum_parity": {
            **{f"{k}_{tag}": v for tag, d in out.items()
               for k, v in d.items()},
            "tokens_match": out["on"]["tokens"] == out["off"]["tokens"],
            "final_loss_delta": round(delta, 4),
        },
    }


def _boundary_rows() -> list[dict]:
    """Full-scale Appendix-F ladder: bytes moved at every stage boundary."""
    cfg = get_config("lwm-7b")
    model = build_model(cfg)
    policies = {}
    for seq in FULL_SEQS:
        data, tp = FULL_SPLITS[seq]
        rows = TOKENS_PER_BATCH // seq
        policies[seq] = (policy_for_stage(cfg, _MeshShape(data, tp), seq, rows),
                         (data, tp), rows)
    rows_out = []
    for prev, nxt in zip(FULL_SEQS, FULL_SEQS[1:]):
        src, src_split, src_rows = policies[prev]
        dst, dst_split, dst_rows = policies[nxt]
        plan = reshard_plan(model, src, dst)
        rows_out.append({
            "bench": "context_stages",
            "analytic_boundary": {
                "from_seq": prev, "to_seq": nxt,
                "from_mesh": {"data": src_split[0], "model": src_split[1]},
                "to_mesh": {"data": dst_split[0], "model": dst_split[1]},
                "from_policy": "ring" if src.ring_axis else "fsdp",
                "to_policy": "ring" if dst.ring_axis else "fsdp",
                "from_batch_rows": src_rows, "to_batch_rows": dst_rows,
                **plan,
                "reshard_beats_replicate":
                    plan["reshard_bytes_per_device"]
                    < plan["replicate_bytes_per_device"],
            },
        })
    return rows_out


def run(*, vision: bool = False, steps: int = 20, quick: bool = False,
        dry_run: bool = False) -> list[dict]:
    if quick:
        steps = 6
    if dry_run:
        # Setup validation in seconds: the analytic boundary plans build
        # (full-scale specs + byte model) and the accum step traces at
        # shape level, without training or writing JSON.
        import jax
        import jax.numpy as jnp

        from repro.train.train_step import init_train_state, make_train_step

        rows = _boundary_rows()
        cfg = get_reduced("lwm-7b")
        model = build_model(cfg)
        state = jax.eval_shape(
            lambda r: init_train_state(model, r), jax.random.PRNGKey(0))
        a, b, s = 2, 1, 64
        batch = {
            "tokens": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "segment_ids": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "positions": jax.ShapeDtypeStruct((a, b, s), jnp.int32),
            "loss_weights": jax.ShapeDtypeStruct((a, b, s), jnp.float32),
        }
        jax.eval_shape(make_train_step(cfg, accum_steps=a), state, batch)
        return rows + [{"bench": "context_stages", "dry_run": True}]

    rows = _measured_ladder(vision=vision, steps=steps)
    if not vision:
        rows.append(_accum_parity(steps=steps))
        rows.extend(_boundary_rows())
        with open(OUT_PATH, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vision", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(vision=args.vision, steps=args.steps,
                   dry_run=args.dry_run):
        print(json.dumps(row))


if __name__ == "__main__":
    main()
