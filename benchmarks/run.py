"""Benchmark orchestrator: one module per paper table/figure.

    python -m benchmarks.run [--full] [--only needle,...]

Default (quick) mode trims training steps so the whole suite finishes on a
CPU in minutes; --full uses the per-benchmark defaults. Results print as
one dict row per line plus a summary table.

Paper artifact -> module map:
    Table 1/11  progressive text stages      -> context_stages
    Table 7/13  vision-language stages       -> context_stages --vision
    Fig 2/5     single-needle retrieval      -> needle
    Fig 6/T3    multi-needle retrieval       -> needle (multi rows)
    Table 10    masked packing ablation      -> packing_ablation
    Table 6     chat/QA mix trade-off        -> chat_mix
    Fig 9       MFU per stage (roofline)     -> mfu_roofline
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):
    # Direct invocation (``python benchmarks/run.py``): put the repo root on
    # sys.path so the ``benchmarks`` package imports; ``python -m
    # benchmarks.run`` never hits this.
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import (chat_mix, context_stages, decode_fused, mfu_roofline,
                        needle, packing_ablation, ring_fused, serve_batching,
                        serve_chaos, serve_paged, serve_quant,
                        serve_ring_paged, serve_spec)

# name -> (runner(quick), dry_runner(quick) | None). Benches with a dry
# runner validate their setup (shape-level traces + analytic models) in
# seconds without compiling or executing — the CI smoke job.
BENCHES = {
    # stage-ladder runtime accounting -> BENCH_context_stages.json
    "context_stages": (lambda q: context_stages.run(quick=q),
                       lambda q: context_stages.run(quick=q, dry_run=True)),
    "context_stages_vision": (lambda q: context_stages.run(vision=True,
                                                           quick=q), None),
    "needle": (lambda q: needle.run(quick=q), None),
    "packing_ablation": (lambda q: packing_ablation.run(quick=q), None),
    "chat_mix": (lambda q: chat_mix.run(quick=q), None),
    "mfu_roofline": (lambda q: mfu_roofline.run(quick=q), None),
    # XLA-vs-fused RingAttention step accounting -> BENCH_ring_fused.json
    "ring_fused": (lambda q: ring_fused.run(quick=q),
                   lambda q: ring_fused.run(quick=q, dry_run=True)),
    # XLA-vs-fused decode-attention accounting -> BENCH_decode_fused.json
    "decode_fused": (lambda q: decode_fused.run(quick=q),
                     lambda q: decode_fused.run(quick=q, dry_run=True)),
    # static-vs-continuous batching accounting -> BENCH_serve_batching.json
    "serve_batching": (lambda q: serve_batching.run(quick=q),
                       lambda q: serve_batching.run(quick=q, dry_run=True)),
    # contiguous-vs-paged KV residency accounting -> BENCH_serve_paged.json
    "serve_paged": (lambda q: serve_paged.run(quick=q),
                    lambda q: serve_paged.run(quick=q, dry_run=True)),
    # single-vs-ring-sharded paged residency -> BENCH_serve_ring_paged.json
    "serve_ring_paged": (lambda q: serve_ring_paged.run(quick=q),
                         lambda q: serve_ring_paged.run(quick=q,
                                                        dry_run=True)),
    # fault-injection recovery accounting -> BENCH_serve_chaos.json
    "serve_chaos": (lambda q: serve_chaos.run(quick=q),
                    lambda q: serve_chaos.run(quick=q, dry_run=True)),
    # speculative-decoding acceptance accounting -> BENCH_serve_spec.json
    "serve_spec": (lambda q: serve_spec.run(quick=q),
                   lambda q: serve_spec.run(quick=q, dry_run=True)),
    # f32-vs-int8 KV residency + recall accounting -> BENCH_serve_quant.json
    "serve_quant": (lambda q: serve_quant.run(quick=q),
                    lambda q: serve_quant.run(quick=q, dry_run=True)),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="per-benchmark default step counts (slower)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--dry-run", action="store_true",
                    help="setup validation only (no compile/execute/JSON); "
                         "benches without dry-run support are skipped")
    args = ap.parse_args(argv)

    names = list(BENCHES) if not args.only else args.only.split(",")
    quick = not args.full
    all_rows = []
    failures = []
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        if name not in BENCHES:
            failures.append((name, f"unknown benchmark (have: {', '.join(BENCHES)})"))
            print(f"  FAILED: unknown benchmark {name!r}")
            continue
        runner, dry_runner = BENCHES[name]
        if args.dry_run:
            if dry_runner is None:
                print("  (no dry-run support; skipped)")
                continue
            runner = dry_runner
        try:
            rows = runner(quick)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"  FAILED: {e!r}")
            continue
        for row in rows:
            print(" ", row, flush=True)
            all_rows.append(row)
        print(f"  ({time.time() - t0:.1f}s)")

    print(f"\n{len(all_rows)} result rows from {len(names) - len(failures)}"
          f"/{len(names)} benchmarks")
    for name, err in failures:
        print(f"  FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
