"""Paper Figure 9 (MFU per training stage) — roofline edition.

No real TPUs here, so instead of measured MFU we derive, per paper training
stage (Table 11 shapes, 4M-token batches, 32K -> 1M sequence length), the
three roofline terms from the compiled dry-run and report the implied MFU
*bound* (MODEL_FLOPS / (step_time_lb * chips * peak)). The paper's claim —
MFU stays high as context grows because RingAttention overlaps K/V exchange
with blockwise compute — shows up as the collective term staying under the
compute term across stages.

Each row also carries the Pallas-fusion adjusted terms: ``mfu_bound_fused``
(single-sweep flash model) and ``mfu_bound_ring_fused`` (the fused-ring
carry-in/carry-out kernel, including per-step carry round-trips) — the
"vs XLA compiler" delta of paper §3.1.

Runs in a subprocess (needs the 512-device XLA flag before jax init).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


def run(*, quick: bool = False) -> list[dict]:
    env = dict(os.environ, PYTHONPATH=SRC + ":" + os.path.dirname(HERE))
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, os.path.join(HERE, "_stage_dryrun.py")]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3000)
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("STAGE_ROW "):
            row = json.loads(line[len("STAGE_ROW "):])
            row["bench"] = "mfu_roofline"
            rows.append(row)
    if not rows:
        rows = [{"bench": "mfu_roofline", "error": r.stderr[-500:]}]
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(row)


if __name__ == "__main__":
    main()
