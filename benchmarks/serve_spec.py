"""Speculative decoding economics: accepted tokens per target sweep.

At the paper's serving scale the decode step is memory-bound: one new token
costs a full split-K sweep over up to a million cached KV tokens (512 KiB
per token for LWM-7B — half a terabyte of cache traffic per token at 1M).
Verification through the chunked-prefill path prices k extra scan columns
into the SAME sweep, so every accepted draft token amortizes the dominant
cost. This bench prices that trade:

  * measured rows (contiguous AND paged pools) — the reduced-LWM engine
    serves a mixed workload twice: plain greedy baseline vs speculative
    self-drafting (drafter == target: every honest proposal accepted) with
    a ``FaultPlan`` draft-flip schedule forcing real rejections mid-run so
    the rollback path is priced too. The contract: bit-identical greedy
    tokens, > 1 accepted token per verify step, and strictly fewer target
    model calls than the baseline.
  * 1M-context analytic row — full-scale cache-sweep byte model for
    granite-3-2b (160 KB/token cache) drafting for lwm-7b (512 KB/token):
    expected accepted prefix under a per-token agreement rate, cost per
    emitted token in target-sweep units, and the speedup bound.

``--dry-run`` (CI smoke) computes the analytic row only — no model, no
compile, no JSON write.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_serve_spec.json")

NUM_SLOTS = 2
CHUNK = 4
MAX_LEN = 96
BLOCK_SIZE = 8
DRAFT_LEN = 4
# Draft flips scheduled early (spec runs take FEWER target steps than the
# baseline — a late schedule would never fire; see FaultPlan.take_flip's
# deferred semantics).
FLIP_STEPS = (5, 8)

# Analytic stage: cross-model drafting at the paper's 1M-token context.
STAGE_CONTEXT = 1 << 20
STAGE_AGREEMENT = 0.8          # assumed per-token drafter/target agreement


def _requests():
    from repro.serve import Request
    return [
        Request(prompt=np.arange(10, 24, dtype=np.int32), max_new_tokens=12),
        Request(prompt=np.arange(40, 49, dtype=np.int32), max_new_tokens=10),
        Request(prompt=(7 + np.arange(20, dtype=np.int32) * 3).astype(
            np.int32) % 900, max_new_tokens=14),
        Request(prompt=np.arange(200, 212, dtype=np.int32),
                max_new_tokens=8),
    ]


def _measured_row(cfg, params, *, paged: bool) -> dict:
    import jax

    from repro.serve import (CacheConfig, FaultPlan, ServeConfig,
                             ServeEngine, SpecConfig)

    cache = CacheConfig(max_len=MAX_LEN, paged=paged, block_size=BLOCK_SIZE)
    base_eng = ServeEngine(cfg, params, ServeConfig(cache=cache))
    t0 = time.time()
    base = base_eng.serve(_requests(), num_slots=NUM_SLOTS,
                          prefill_chunk=CHUNK)
    base_wall = round(time.time() - t0, 2)

    plan = FaultPlan(flip_steps=FLIP_STEPS)
    spec_eng = ServeEngine(cfg, params, ServeConfig(
        cache=cache, spec=SpecConfig(drafter=cfg, drafter_params=params,
                                     draft_len=DRAFT_LEN, enabled=True)),
        faults=plan)
    t0 = time.time()
    spec = spec_eng.serve(_requests(), num_slots=NUM_SLOTS,
                          prefill_chunk=CHUNK)
    spec_wall = round(time.time() - t0, 2)

    tokens_match = all(
        np.array_equal(b.tokens, s.tokens)
        and b.finish_reason == s.finish_reason
        for b, s in zip(base, spec))
    st = spec_eng.stats
    return {
        "bench": "serve_spec",
        "backend": jax.default_backend(),
        "pool": "paged" if paged else "contiguous",
        "workload": {"requests": len(_requests()), "num_slots": NUM_SLOTS,
                     "prefill_chunk": CHUNK, "max_len": MAX_LEN,
                     "block_size": BLOCK_SIZE, "model": cfg.name,
                     "draft_len": DRAFT_LEN,
                     "drafter": "self (identical params)"},
        "fault_plan": plan.describe(),
        "fired": plan.summary(),
        "baseline": {"model_calls": base_eng.stats["model_calls"],
                     "useful_tokens": base_eng.stats["useful_tokens"],
                     "wall_s": base_wall},
        "spec": {"model_calls": st["model_calls"],
                 "drafter_calls": st["drafter_calls"],
                 "spec_steps": st["spec_steps"],
                 "spec_drafted": st["spec_drafted"],
                 "spec_accepted": st["spec_accepted"],
                 "spec_rollbacks": st["spec_rollbacks"],
                 "spec_rollback_tokens": st["spec_rollback_tokens"],
                 "spec_blocks_freed": st["spec_blocks_freed"],
                 "useful_tokens": st["useful_tokens"],
                 "wall_s": spec_wall},
        "delta": {
            "tokens_match": tokens_match,
            "accepted_per_spec_step": st["accepted_per_spec_step"],
            "rollbacks": int(st["spec_rollbacks"]),
            "target_calls_saved": int(base_eng.stats["model_calls"]
                                      - st["model_calls"]),
        },
    }


# ---------------------------------------------------------------------------
# 1M-context analytic row: cross-model drafting byte economics (no arrays)
# ---------------------------------------------------------------------------

def _kv_bytes_per_token(cfg) -> int:
    # K + V per layer, bf16.
    return 2 * cfg.num_kv_heads * cfg.head_dim * 2 * cfg.num_layers


def _paper_stage_row(*, context=STAGE_CONTEXT, draft_len=DRAFT_LEN,
                     agreement=STAGE_AGREEMENT) -> dict:
    from repro.configs import get_config

    target = get_config("lwm-7b")
    drafter = get_config("granite-3-2b")
    tb = _kv_bytes_per_token(target)       # bytes swept per cached token
    db = _kv_bytes_per_token(drafter)
    r = db / tb                            # drafter sweep / target sweep
    # Expected accepted prefix length under i.i.d. per-token agreement a:
    # E[m] = a + a^2 + ... + a^k; every verify step emits m + 1 tokens.
    e_accept = sum(agreement ** j for j in range(1, draft_len + 1))
    emitted = e_accept + 1.0
    # Cost per verify cycle in target-sweep units: the verify step is ONE
    # sweep (extra chunk columns ride it) + k drafter sweeps at ratio r.
    cycle_cost = 1.0 + draft_len * r
    speedup = emitted / cycle_cost
    plain_bytes = context * tb             # cache traffic per emitted token
    spec_bytes = context * (tb + draft_len * db) / emitted
    return {
        "bench": "serve_spec",
        "analytic_paper_stage": {
            "workload": {"context_tokens": context, "draft_len": draft_len,
                         "agreement_rate": agreement,
                         "target": target.name, "drafter": drafter.name,
                         "target_kv_bytes_per_token": tb,
                         "drafter_kv_bytes_per_token": db},
            "expected_accepted_per_step": round(e_accept, 4),
            "tokens_per_target_sweep": round(emitted, 4),
            "drafter_sweep_cost_ratio": round(r, 6),
            "plain_sweep_bytes_per_token": int(plain_bytes),
            "spec_sweep_bytes_per_token": int(spec_bytes),
            "delta": {
                "tokens_per_sweep_gt_1": emitted > 1.0,
                "sweep_speedup": round(speedup, 4),
                "sweep_bytes_reduction": round(plain_bytes / spec_bytes, 4),
            },
        },
    }


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    if dry_run:
        # Analytic byte model only: same code path the gate reads, CI-sized.
        return [{"bench": "serve_spec", "dry_run": True,
                 **_paper_stage_row()}]
    import jax

    from repro.configs import get_reduced
    from repro.models.registry import build_model

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = [_measured_row(cfg, params, paged=False),
            _measured_row(cfg, params, paged=True),
            _paper_stage_row()]
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
