import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Subprocess worker for benchmarks/mfu_roofline.py: lowers the LWM-7B
# train_step at each paper stage shape (Table 11: 4M tokens per batch,
# seq 32K..1M) on the production mesh and prints one JSON row per stage.
# Long stages (>=128K) use the paper's regime: RingAttention sequence
# sharding (train_ring policy).
import json
import sys

from repro.configs import InputShape, get_config
from repro.launch.dryrun import run_one

STAGES = [  # (name, seq_len, rope_theta, policy)
    ("32K", 2 ** 15, 1e6, "train"),
    ("128K", 2 ** 17, 1e7, "train_ring"),
    ("256K", 2 ** 18, 1e7, "train_ring"),
    ("512K", 2 ** 19, 2.5e7, "train_ring"),
    ("1M", 2 ** 20, 5e7, "train_ring"),
]
TOKENS_PER_BATCH = 4 * 2 ** 20          # paper: 4M tokens per batch


def main():
    from repro.launch.fusion import (FusionAdjustment, ring_flash_io_bytes,
                                     stage_fusion_adjustment)
    from repro.launch.roofline import PEAK_FLOPS

    quick = "--quick" in sys.argv
    stages = STAGES[:2] if quick else STAGES
    for name, seq, theta, policy in stages:
        gb = max(TOKENS_PER_BATCH // seq, 1)
        import repro.configs as C
        shape = InputShape(f"stage_{name}", seq, gb, "train")
        C.INPUT_SHAPES[shape.name] = shape
        cfg = get_config("lwm-7b").replace(rope_theta=theta, max_context=seq)
        r = run_one("lwm-7b", shape.name, "pod1", policy_kind=policy,
                    cfg_override=cfg, verbose=False)
        roof = r.to_roofline()
        row = {"stage": name, "seq_len": seq, "global_batch": gb,
               "policy": policy, "ok": r.ok, "error": r.error,
               **(roof.row() if r.ok else {})}
        if r.ok:
            # Pallas-fusion adjustment (paper §3.1 "vs XLA compiler"):
            # measured XLA attention traffic swapped for the flash kernel's
            # analytic VMEM-resident IO.
            ring = 16 if policy == "train_ring" else 1
            bsh = 1 if policy == "train_ring" else 16
            adj = stage_fusion_adjustment(cfg, seq_len=seq, global_batch=gb,
                                          ring_devices=ring,
                                          batch_shards=bsh)
            fused_mem = adj.fused_memory_s(roof.memory_s)
            row["xla_attn_TB"] = round(adj.xla_attn_bytes / 1e12, 2)
            row["flash_attn_TB"] = round(adj.flash_attn_bytes / 1e12, 3)
            row["memory_s_fused"] = round(fused_mem, 3)
            terms = {"compute": roof.compute_s, "memory": fused_mem,
                     "collective": roof.collective_s}
            row["bottleneck_fused"] = max(terms, key=terms.get)
            step_lb = max(terms.values())
            row["mfu_bound_fused"] = round(
                float(row_model_flops(r)) / (step_lb * 256 * PEAK_FLOPS), 4)
            # Fused-ring engine (carry-in/carry-out kernel per arriving
            # shard): per-step carry round-trips included, vs the
            # single-sweep flash model above.
            b_local = max(gb // bsh, 1)
            ring_fused_total = ring_flash_io_bytes(
                s_local=seq // ring, ring_devices=ring,
                num_q_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim,
                batch_per_device=b_local) * cfg.num_layers
            ring_adj = FusionAdjustment(
                xla_attn_bytes=adj.xla_attn_bytes,
                flash_attn_bytes=ring_fused_total, layers=cfg.num_layers)
            mem_rf = ring_adj.fused_memory_s(roof.memory_s)
            row["ring_fused_attn_TB"] = round(ring_fused_total / 1e12, 3)
            row["memory_s_ring_fused"] = round(mem_rf, 3)
            step_lb_rf = max(roof.compute_s, mem_rf, roof.collective_s)
            row["mfu_bound_ring_fused"] = round(
                float(row_model_flops(r)) / (step_lb_rf * 256 * PEAK_FLOPS), 4)
        print("STAGE_ROW " + json.dumps(row), flush=True)


def row_model_flops(r):
    return r.model_flops


if __name__ == "__main__":
    main()
