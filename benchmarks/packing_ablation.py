"""Paper Table 10: masked sequence packing vs naive packing.

The paper's ablation shows naive packing degrades tasks whose answers are
short (image understanding): token-mean weighting drowns the few answer
tokens under dense long-segment loss tokens. We reproduce the mechanism:

  * mixture: long filler documents (every token carries loss) packed
    together with short-answer retrieval examples (loss only on 3 answer
    tokens);
  * two models trained identically except the packing loss mode;
  * metric: answer-token accuracy on held-out short-answer examples.

Masked packing must win on answer accuracy (paper: 55.8 vs 48.3 VQAv2 etc.).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.packing import packed_loss_weights
from repro.data.books import BookSampler
from repro.data.needle import NeedleTask, retrieval_accuracy
from repro.data.packing import Example, pack_examples
from repro.data.vocab import build_vocab
from repro.models.registry import build_model
from repro.train.train_step import init_train_state, make_eval_step, make_train_step

import jax.numpy as jnp

SEQ = 256
ANSWER_SEQ = 64


def _mixed_batch(nt, books, vocab, rows, rng, mode):
    """Rows packing long filler segments + short needle examples."""
    examples = []
    for _ in range(rows * 3):
        if rng.random() < 0.5:
            doc = books.sample_document(int(rng.integers(100, 200)))
            examples.append(Example(doc))
        else:
            ex = nt.build(ANSWER_SEQ, num_needles=1, num_retrieve=1)
            examples.append(Example(ex.tokens, ex.loss_mask))
    batch = pack_examples(examples, vocab=vocab, seq_len=SEQ, batch_rows=rows)
    w = packed_loss_weights(jnp.asarray(batch.segment_ids),
                            jnp.asarray(batch.loss_mask),
                            max_segments=batch.num_segments + 2, mode=mode)
    return {
        "tokens": batch.tokens, "labels": batch.labels,
        "segment_ids": batch.segment_ids, "positions": batch.positions,
        "loss_weights": np.asarray(w, np.float32),
    }


def run(*, steps: int = 600, rows: int = 4, quick: bool = False) -> list[dict]:
    from benchmarks.needle import answer_logprob

    if quick:
        steps = 200
    cfg = get_reduced("lwm-7b")
    vocab = build_vocab(cfg.vocab_size, 0)
    nt = NeedleTask(vocab, seed=0, key_len=1, val_len=1)
    books = BookSampler(vocab, 100, 200, seed=5)
    model = build_model(cfg)
    eval_step = jax.jit(make_eval_step(cfg))

    results = []
    for mode in ("naive", "masked"):
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, learning_rate=3e-3,
                                       weight_decay=0.0))
        rng = np.random.default_rng(0)
        for _ in range(steps):
            state, m = step(state, _mixed_batch(nt, books, vocab, rows, rng,
                                                mode))
        # eval: unpacked short-answer retrieval (accuracy + answer log-prob —
        # the mechanism Table 10 measures: naive packing starves the short
        # answers of gradient signal)
        accs, lps, answer_ce = [], [], []
        for _ in range(6):
            b = nt.batch(rows, ANSWER_SEQ, num_needles=1, num_retrieve=1)
            eb = {
                "tokens": b["tokens"],
                "labels": np.roll(b["tokens"], -1, axis=1),
                "segment_ids": np.ones_like(b["tokens"]),
                "positions": np.tile(np.arange(ANSWER_SEQ, dtype=np.int32),
                                     (rows, 1)),
                "loss_weights": np.roll(b["loss_mask"], -1,
                                        axis=1).astype(np.float32),
            }
            logits, met = eval_step(state.params, eb)
            accs.append(retrieval_accuracy(np.asarray(logits, np.float32), b))
            lps.append(answer_logprob(np.asarray(logits, np.float32), b))
            answer_ce.append(float(met["loss"]))
        results.append({"bench": "packing_ablation", "mode": mode,
                        "answer_acc": round(float(np.mean(accs)), 3),
                        "answer_logprob": round(float(np.mean(lps)), 3),
                        "answer_ce": round(float(np.mean(answer_ce)), 4)})
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args(argv)
    for row in run(steps=args.steps):
        print(row)


if __name__ == "__main__":
    main()
