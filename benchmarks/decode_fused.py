"""XLA-vs-fused decode-attention accounting (paper §5 serving path).

One decode step = one new token's attention against the KV cache. Two
engines compute it:

  * "xla"   — ``core.decode.decode_attend_local``: einsum over the full
              cache; the (B, 1, H, L) f32 logits — and the f32 repeat_kv
              expansion of the cache — materialize in HBM.
  * "fused" — ``kernels.flash_decode``: one split-K Pallas invocation; the
              cache streams through VMEM blocks, logits tiles never leave
              VMEM, only O(splits * H * D) partial statistics round-trip
              (lowered here via interpret mode, whose HLO has the same
              tile-level buffers).

Both are lowered and walked with the HLO cost model at 32K and 128K cache
lengths (compile-only — nothing executes at 128K); timing runs at the
smallest length. The 1M row is the analytic byte model only (the same model
is validated against the measured lengths). The materialized-logits
detector counts f32 buffers >= B*H*L elements — the per-layer logits the
fused path must eliminate. Results land in ``BENCH_decode_fused.json``.

``--dry-run`` (CI smoke): build every step function, abstractly evaluate it
(shape-level trace of the kernel wrapper), and emit the analytic rows —
no compilation, no execution, no JSON write.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_decode_fused.json")

B, H, HKV, D = 1, 8, 2, 64
NUM_SPLITS = 8
KV_BLOCK = 512
CACHE_LENS = (32 * 1024, 128 * 1024, 1024 * 1024)
FILL = 0.75            # fraction of the cache that holds written entries


def _mk_inputs(cache_len: int, *, abstract: bool = False):
    """Step inputs; ``abstract=True`` returns ShapeDtypeStructs (no 1M-entry
    cache ever allocates for dry-run / analytic-only rows)."""
    if abstract:
        return (jax.ShapeDtypeStruct((B, 1, H, D), jnp.bfloat16),
                jax.ShapeDtypeStruct((B, cache_len, HKV, D), jnp.bfloat16),
                jax.ShapeDtypeStruct((B, cache_len, HKV, D), jnp.bfloat16),
                jax.ShapeDtypeStruct((B, cache_len), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32))
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, 1, H, D), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, cache_len, HKV, D),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, cache_len, HKV, D),
                          jnp.bfloat16)
    kvpos = jnp.broadcast_to(jnp.arange(cache_len, dtype=jnp.int32),
                             (B, cache_len))
    filled = int(cache_len * FILL)
    kvpos = jnp.where(kvpos < filled, kvpos, -1)
    qpos = jnp.full((B,), filled - 1, jnp.int32)
    return q, k, v, kvpos, qpos


def _xla_step(cache_len: int, *, abstract: bool = False):
    from repro.core import decode as dec

    def step(q, k, v, kvpos, qpos):
        return dec.decode_attention_unsharded(
            q, k, v, kv_positions=kvpos, q_position=qpos, impl="xla")

    return step, _mk_inputs(cache_len, abstract=abstract)


def _fused_step(cache_len: int, *, abstract: bool = False):
    from repro.kernels import flash_decode as fdk

    def step(q, k, v, kvpos, qpos):
        return fdk.flash_decode(
            q, k, v, kvpos, qpos, kv_block=KV_BLOCK, num_splits=NUM_SPLITS,
            interpret=jax.default_backend() != "tpu")

    return step, _mk_inputs(cache_len, abstract=abstract)


def _account(step, args, *, cache_len: int, iters: int) -> dict:
    from repro.launch import hlo as hlo_mod

    compiled = jax.jit(step).lower(*args).compile()
    text = compiled.as_text()
    cost = hlo_mod.full_cost(text, num_devices=1)
    logits = hlo_mod.materialized_buffer_bytes(
        text, min_elems=B * H * cache_len, dtype="f32")
    row = {
        "bytes_accessed": cost.bytes_accessed,
        "flops": cost.flops,
        "logits_buffer_bytes": logits["bytes"],
        "logits_buffer_count": logits["count"],
    }
    if iters > 0:
        out = jax.block_until_ready(compiled(*args))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = compiled(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / iters
        row["step_ms"] = round(dt * 1e3, 3)
    return row


def _analytic(cache_len: int) -> dict:
    from repro.launch import fusion as fusion_mod

    kw = dict(cache_len=cache_len, num_q_heads=H, num_kv_heads=HKV,
              head_dim=D, batch_per_device=B, dtype_bytes=2)
    xla = fusion_mod.xla_decode_io_bytes(**kw)
    fused = fusion_mod.flash_decode_io_bytes(**kw, num_splits=NUM_SPLITS)
    return {"xla_bytes_model": xla, "fused_bytes_model": fused,
            "bytes_saved_model": xla - fused,
            "fused_speedup_bound": round(xla / max(fused, 1.0), 2)}


def _paper_stage_row() -> dict:
    """Analytic whole-model projection: LWM-7B serving a 1M-token context
    with the cache sequence-sharded 4 ways (the paper's §5 ring width) —
    per-device, per-decode-step bytes across all layers."""
    from repro.configs import get_config
    from repro.launch import fusion as fusion_mod

    cfg = get_config("lwm-7b")
    return {
        "bench": "decode_fused",
        "analytic_paper_stage": fusion_mod.decode_fusion_summary(
            cfg, cache_len=1024 * 1024, batch_per_device=1, ring_devices=4,
            num_splits=NUM_SPLITS),
        "model": cfg.name,
        "layers": cfg.num_layers,
    }


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    rows = []
    measure_lens = CACHE_LENS[:1] if quick else CACHE_LENS[:2]
    for cache_len in CACHE_LENS:
        row = {
            "bench": "decode_fused",
            "shape": {"b": B, "h": H, "hkv": HKV, "d": D,
                      "cache_len": cache_len, "kv_block": KV_BLOCK,
                      "num_splits": NUM_SPLITS, "fill": FILL},
            "backend": jax.default_backend(),
            "analytic": _analytic(cache_len),
        }
        if dry_run:
            # Shape-level trace only: validates the kernel wrapper builds
            # for this cache length without compiling or executing.
            xla_step, xla_args = _xla_step(cache_len, abstract=True)
            fused_step, fused_args = _fused_step(cache_len, abstract=True)
            jax.eval_shape(xla_step, *xla_args)
            jax.eval_shape(fused_step, *fused_args)
            row["dry_run"] = True
        elif cache_len in measure_lens:
            xla_step, xla_args = _xla_step(cache_len)
            fused_step, fused_args = _fused_step(cache_len)
            iters = (3 if quick else 10) if cache_len == CACHE_LENS[0] else 0
            xla = _account(xla_step, xla_args, cache_len=cache_len,
                           iters=iters)
            fused = _account(fused_step, fused_args, cache_len=cache_len,
                             iters=iters)
            if jax.default_backend() != "tpu":
                fused["bytes_accessed_note"] = (
                    "interpret-mode overcount; see analytic.fused_bytes_model")
            row["xla"] = xla
            row["fused"] = fused
            row["delta"] = {
                "logits_buffer_bytes_eliminated":
                    xla["logits_buffer_bytes"] - fused["logits_buffer_bytes"],
                "fused_eliminates_logits_buffer":
                    xla["logits_buffer_count"] > 0
                    and fused["logits_buffer_count"] == 0,
            }
        else:
            row["analytic_only"] = True
        rows.append(row)
    rows.append(_paper_stage_row())

    if not dry_run:
        with open(OUT_PATH, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
