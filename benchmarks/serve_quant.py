"""f32-vs-int8 KV-cache residency + recall accounting (ROADMAP item 2).

At 1M context the paged pool still charges ~2 bytes/element of KV per
token; HBM — not FLOPs — caps concurrent users per device. Quantizing the
cache to int8 with one f32 scale per (block, layer, head) halves the
resident bytes, compounding multiplicatively with paged prefix sharing
(BENCH_serve_paged.json), at the cost of ~7-bit K/V mantissas for
everything outside the full-precision tail window.

Two gates, both fail-closed in ``tools/check_bench.py``:

  * measured bytes — both engines serve the same long-prompt workload on
    the reduced LWM; resident-KV bytes are measured from the REAL pool
    buffers (`.nbytes` of the int8 stores + scale rows + tail ring vs the
    bf16 stores) at the run's peak live-block count. Gate: int8 bytes per
    resident token <= 0.55x f32.
  * recall — a hand-programmed retrieval-head model
    (``benchmarks/needle.py::programmed_retrieval_model``: fixed-offset
    RoPE addressing + value-code copy, recall 1.0 by construction in f32)
    is served through both pools; recall = exact greedy retrieval of the
    hidden needle value through the real engine, with the needle far
    outside the full-precision tail window so int8 K (addressing) and V
    (copied code) fidelity are both on the line. Gate: f32 recall >= 0.9
    and int8 recall within 2 points of f32.

``--dry-run`` (CI smoke) traces the quantized paged prefill step at the
shape level and replays the analytic byte model — no train, no compile,
no JSON write.
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_serve_quant.json")

# Measured workload: long prompts (relative to the tail window) so nearly
# the whole resident cache is int8 — bytes-per-token then approaches the
# asymptotic ratio instead of being dominated by the fixed tail ring.
NUM_SLOTS = 2
CHUNK = 32
MAX_LEN = 384
BLOCK_SIZE = 16
TAIL_BLOCKS = 1
PROMPT_LEN = 376
MAX_NEW = 8
# Enough physical blocks that BOTH slots admit concurrently (each request
# reserves blocks(prompt) + 1 headroom = 25; the default pool of 48 would
# serialize them and halve the peak-resident denominator).
NUM_BLOCKS = NUM_SLOTS * (MAX_LEN // BLOCK_SIZE) + 4

# Recall workload (needle grammar, (1,1) variant, programmed head). The
# fixed depth puts the needle ~100 positions behind the generating token —
# far outside the 16-token full-precision tail, in fully-flushed int8
# blocks.
RETRIEVAL_SEQ = 128
RETRIEVAL_DEPTH = 0.2
RETRIEVAL_ROWS = 8
RETRIEVAL_BATCHES = 8

# 1M-context analytic dims (full-scale model).
STAGE_CACHE_LEN = 1 << 20
STAGE_BLOCK = 256


def _pool_bytes(caches) -> tuple[int, int]:
    """(bytes per physical block, fixed tail-ring bytes) measured from the
    real device buffers of a paged pool. Block-resident leaves (k/v pools
    and, under quant, their scale rows) are keyed by physical block on
    axis 1; the full-precision tail ring is per-slot fixed overhead."""
    block = tail = 0
    for group in caches.values():
        for name, leaf in group.items():
            if name in ("k", "v", "k_scale", "v_scale"):
                block += leaf.nbytes // leaf.shape[1]
            elif name in ("k_tail", "v_tail"):
                tail += leaf.nbytes
    return block, tail


def _cache_config(quant: str):
    from repro.serve import CacheConfig
    return CacheConfig(max_len=MAX_LEN, paged=True, block_size=BLOCK_SIZE,
                       num_blocks=NUM_BLOCKS, quant=quant,
                       quant_tail_blocks=TAIL_BLOCKS)


def _requests():
    from repro.serve import Request
    rng = np.random.default_rng(7)
    return [Request(prompt=rng.integers(16, 900, PROMPT_LEN).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for _ in range(NUM_SLOTS)]


def _measured_row() -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.models.registry import build_model
    from repro.serve import PagedCachePool, ServeConfig, ServeEngine

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    sides = {}
    tokens = {}
    for quant in ("none", "int8"):
        eng = ServeEngine(cfg, params,
                          ServeConfig(cache=_cache_config(quant)))
        t0 = time.time()
        res = eng.serve(_requests(), num_slots=NUM_SLOTS,
                        prefill_chunk=CHUNK)
        wall = round(time.time() - t0, 2)
        tokens[quant] = [r.tokens for r in res]
        # Resident bytes from the real buffers: one throwaway pool per
        # variant (reduced scale — a few MB) gives the exact per-block and
        # tail-ring footprint the engine's pool allocated.
        pool = PagedCachePool(NUM_SLOTS, cfg=cfg, max_len=MAX_LEN,
                              block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS,
                              quant=quant, quant_tail_blocks=TAIL_BLOCKS)
        block_bytes, tail_bytes = _pool_bytes(pool.caches)
        del pool
        peak = int(eng.stats["peak_live_blocks"])
        resident = peak * block_bytes + tail_bytes
        live_tokens = peak * BLOCK_SIZE
        sides[quant] = {
            "resident_kv_bytes": int(resident),
            "bytes_per_token": round(resident / max(live_tokens, 1), 1),
            "peak_live_blocks": peak,
            "block_bytes": int(block_bytes),
            "tail_ring_bytes": int(tail_bytes),
            "wall_s": wall,
        }
    match = all(np.array_equal(a, b)
                for a, b in zip(tokens["none"], tokens["int8"]))
    f32_bpt = sides["none"]["bytes_per_token"]
    int8_bpt = sides["int8"]["bytes_per_token"]
    return {
        "bench": "serve_quant",
        "backend": jax.default_backend(),
        "workload": {"requests": NUM_SLOTS, "num_slots": NUM_SLOTS,
                     "prompt_len": PROMPT_LEN, "max_new": MAX_NEW,
                     "prefill_chunk": CHUNK, "max_len": MAX_LEN,
                     "block_size": BLOCK_SIZE, "num_blocks": NUM_BLOCKS,
                     "quant_tail_blocks": TAIL_BLOCKS, "model": cfg.name},
        "f32": sides["none"],
        "int8": sides["int8"],
        "delta": {
            "tokens_match": bool(match),
            "bytes_per_token_cut": round(f32_bpt / max(int8_bpt, 1e-9), 3),
            "int8_over_f32": round(int8_bpt / max(f32_bpt, 1e-9), 4),
        },
    }


def _recall_row(*, seq=RETRIEVAL_SEQ, depth=RETRIEVAL_DEPTH,
                rows=RETRIEVAL_ROWS, batches=RETRIEVAL_BATCHES) -> dict:
    from benchmarks import needle

    pm = needle.programmed_retrieval_model(seq=seq, depth=depth)
    cfg, params, task = pm["cfg"], pm["params"], pm["task"]
    import dataclasses
    f32_cache = dataclasses.replace(_cache_config("none"), max_len=seq + 8)
    int8_cache = dataclasses.replace(_cache_config("int8"), max_len=seq + 8)
    recall = {}
    for name, cache in (("f32", f32_cache), ("int8", int8_cache)):
        recall[name] = needle.serve_retrieval(
            cfg, params, task, seq=seq, cache=cache, rows=rows,
            batches=batches, depth=depth)
    return {
        "bench": "serve_quant",
        "retrieval": {
            "programmed_head": True, "seq": seq, "depth": depth,
            "needle_offset": pm["offset"],
            "addressing_margin": pm["margin"],
            "retrievals": rows * batches,
            "recall_f32": round(recall["f32"], 4),
            "recall_int8": round(recall["int8"], 4),
            "recall_delta": round(recall["int8"] - recall["f32"], 4),
        },
    }


def _analytic_row(*, cache_len=STAGE_CACHE_LEN, block=STAGE_BLOCK,
                  tail_blocks=2) -> dict:
    """1M-context byte model at full-scale LWM-7B cache dims: resident
    pool bytes per token and per-step decode HBM traffic, f32 vs int8."""
    from repro.configs import get_config
    from repro.launch import fusion

    cfg = get_config("lwm-7b")
    hkv, hd, layers = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    f32_bpt = layers * 2 * hkv * hd * dtype_bytes
    int8_bpt = layers * 2 * hkv * (hd + 4 / block)      # int8 + scale share
    tail = tail_blocks * block
    kw = dict(cache_len=cache_len, num_q_heads=cfg.num_heads,
              num_kv_heads=hkv, head_dim=hd, batch_per_device=1)
    io_f32 = fusion.flash_decode_io_bytes(**kw) * layers
    io_int8 = fusion.flash_decode_io_bytes(
        **kw, quant=True, quant_block=block, quant_tail_len=tail) * layers
    return {
        "bench": "serve_quant",
        "analytic_1m": {
            "model": cfg.name, "cache_len": cache_len, "block_size": block,
            "quant_tail_blocks": tail_blocks,
            "f32_kv_bytes_per_token": int(f32_bpt),
            "int8_kv_bytes_per_token": round(int8_bpt, 1),
            "resident_cut": round(f32_bpt / int8_bpt, 3),
            "decode_io_bytes_f32": io_f32,
            "decode_io_bytes_int8": io_int8,
            "decode_io_cut": round(io_f32 / io_int8, 3),
        },
    }


def _dry_run_trace() -> None:
    """Shape-level trace of the quantized paged prefill step (no compile)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import decoding
    from repro.models.registry import build_model

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    nb = NUM_SLOTS * (MAX_LEN // BLOCK_SIZE)
    caches = jax.eval_shape(functools.partial(
        decoding.init_paged_caches, cfg, nb, BLOCK_SIZE, quant="int8",
        batch=NUM_SLOTS, quant_tail_blocks=TAIL_BLOCKS))
    jax.eval_shape(
        functools.partial(decoding.prefill_step, cfg),
        params,
        jax.ShapeDtypeStruct((NUM_SLOTS, CHUNK), jnp.int32),
        caches,
        jax.ShapeDtypeStruct((NUM_SLOTS,), jnp.int32),
        jax.ShapeDtypeStruct((NUM_SLOTS,), jnp.int32),
        block_tables=jax.ShapeDtypeStruct((NUM_SLOTS, MAX_LEN // BLOCK_SIZE),
                                          jnp.int32))


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    if dry_run:
        _dry_run_trace()
        return [{"bench": "serve_quant", "dry_run": True,
                 **_analytic_row(cache_len=1 << 12, block=32)}]
    rows = [_measured_row(),
            _recall_row(batches=2 if quick else RETRIEVAL_BATCHES),
            _analytic_row()]
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
