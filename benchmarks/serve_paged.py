"""Contiguous-vs-paged KV residency accounting (paper §5 serving at scale).

The contiguous slot pool (`ServeEngine(paged=False)`) preallocates a full
``max_len`` KV reservation per slot: a freshly-admitted 10-token request
pays 1M-context memory from token one, and identical video prompts (many
users chatting over the same hour-long video) are duplicated per slot. The
paged pool (`paged=True`) stores KV in fixed-size blocks behind per-slot
block tables with refcounted prefix sharing, so resident bytes track *live*
tokens and a shared 1M-token video prefix is stored once.

The unit of accounting is **resident KV bytes per concurrent request**:
bytes the cache pool must hold per in-flight request at the run's peak.

  * measured row — both engines serve the same shared-prefix workload on
    the reduced LWM (CPU-sized); the paged side reports peak *live* block
    bytes, the contiguous side its per-slot reservation; greedy tokens must
    match exactly.
  * 1M analytic row — the REAL ``Scheduler`` replays a
    16-users-one-video workload (1M-token shared video prompt + unique
    question tails, staggered arrivals) against a bookkeeping-only
    ``PagedCachePool``; byte totals use the full-scale LWM-7B cache dims.
    ``tools/check_bench.py`` gates the committed JSON on >= 8x reduction
    with replayed token parity.

``--dry-run`` (CI smoke) runs a scaled-down analytic replay plus a
shape-level trace of the paged prefill step — no compile, no JSON write.
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_serve_paged.json")

# Measured small-scale workload: two identical prompts, two sharing a
# 16-token prefix then diverging, two unrelated — on 3 slots so admission
# interleaves with retirement and the prefix registry actually gets hits.
NUM_SLOTS = 3
CHUNK = 4
MAX_LEN = 96
BLOCK_SIZE = 8

# Paper-stage analytic workload: one hour-long video (paper §1: 1M-token
# context) chatted over by many concurrent users, each with a unique
# question tail. Stage arrivals so later users join once the first user's
# prefill has populated the prefix registry (the steady-state of a busy
# video-QA service).
STAGE_USERS = 16
STAGE_VIDEO_TOKENS = 1 << 20
STAGE_QUESTION_TOKENS = 512
STAGE_MAX_NEW = 256
STAGE_CHUNK = 4096
STAGE_BLOCK = 256


def _bytes_per_token(cfg) -> int:
    """Per-token KV footprint across every attention layer (k + v)."""
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    return (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
            * dtype_bytes)


# ---------------------------------------------------------------------------
# Measured small-scale run (real engines, reduced model)
# ---------------------------------------------------------------------------

def _requests():
    from repro.serve import Request
    shared = (7 + np.arange(24, dtype=np.int32) * 3) % 900
    fork = np.concatenate([shared[:16],
                           np.arange(500, 510, dtype=np.int32)])
    return [
        Request(prompt=shared, max_new_tokens=6),
        Request(prompt=np.arange(40, 75, dtype=np.int32), max_new_tokens=4),
        Request(prompt=shared.copy(), max_new_tokens=5),
        Request(prompt=fork.astype(np.int32), max_new_tokens=6),
        Request(prompt=np.arange(200, 212, dtype=np.int32), max_new_tokens=3),
        Request(prompt=shared.copy(), max_new_tokens=4),
    ]


def _measured_row() -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.models.registry import build_model
    from repro.serve import CacheConfig, ServeConfig, ServeEngine

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bpt = _bytes_per_token(cfg)

    cont_eng = ServeEngine(cfg, params,
                           ServeConfig(cache=CacheConfig(max_len=MAX_LEN)))
    t0 = time.time()
    cont_res = cont_eng.serve(_requests(), num_slots=NUM_SLOTS,
                              prefill_chunk=CHUNK)
    cont_wall = round(time.time() - t0, 2)

    paged_eng = ServeEngine(cfg, params, ServeConfig(cache=CacheConfig(
        max_len=MAX_LEN, paged=True, block_size=BLOCK_SIZE)))
    t0 = time.time()
    paged_res = paged_eng.serve(_requests(), num_slots=NUM_SLOTS,
                                prefill_chunk=CHUNK)
    paged_wall = round(time.time() - t0, 2)

    tokens_match = all(np.array_equal(c.tokens, p.tokens)
                       for c, p in zip(cont_res, paged_res))
    cont_bytes = NUM_SLOTS * MAX_LEN * bpt       # full per-slot reservation
    peak_blocks = paged_eng.stats["peak_live_blocks"]
    paged_bytes = peak_blocks * BLOCK_SIZE * bpt
    return {
        "bench": "serve_paged",
        "backend": jax.default_backend(),
        "workload": {"requests": len(_requests()), "num_slots": NUM_SLOTS,
                     "prefill_chunk": CHUNK, "max_len": MAX_LEN,
                     "block_size": BLOCK_SIZE, "model": cfg.name,
                     "kv_bytes_per_token": bpt},
        "contiguous": {"resident_kv_bytes": cont_bytes,
                       "resident_kv_bytes_per_request": cont_bytes // NUM_SLOTS,
                       "wall_s": cont_wall},
        "paged": {"resident_kv_bytes": paged_bytes,
                  "resident_kv_bytes_per_request": paged_bytes // NUM_SLOTS,
                  "peak_live_blocks": int(peak_blocks),
                  "prefix_hit_tokens": paged_eng.stats["prefix_hit_tokens"],
                  "wall_s": paged_wall},
        "delta": {
            "tokens_match": tokens_match,
            "paged_strictly_fewer_resident_bytes": paged_bytes < cont_bytes,
            "bytes_reduction": round(cont_bytes / max(paged_bytes, 1), 2),
        },
    }


# ---------------------------------------------------------------------------
# 1M-context shared-prefix analytic replay (real scheduler, no arrays)
# ---------------------------------------------------------------------------

def _stage_replay(*, users, video_tokens, question_tokens, max_new, chunk,
                  block_size) -> dict:
    """Replay the REAL scheduler over the shared-video workload against a
    bookkeeping-only PagedCachePool and record the peak live-block count
    alongside the useful-token total."""
    from repro.serve import PagedCachePool, Request, Scheduler

    video = ((np.arange(video_tokens, dtype=np.int64) * 2654435761) % 65521
             ).astype(np.int32)
    max_len = video_tokens + question_tokens + max_new
    blocks_per_user = -(-max_len // block_size)
    # Physical pool sized for one video + per-user tails (admission by free
    # blocks keeps everyone inside it) — NOT users * blocks_per_user.
    num_blocks = blocks_per_user + users * (
        -(-(question_tokens + max_new) // block_size) + 4)
    pool = PagedCachePool(users, max_len=max_len, block_size=block_size,
                          num_blocks=num_blocks)
    sched = Scheduler(pool, prefill_chunk=chunk, vocab_size=65536)

    def make_req(u):
        q = (np.arange(question_tokens, dtype=np.int32) + 7919 * (u + 1)) % 65521
        return Request(prompt=np.concatenate([video, q]),
                       max_new_tokens=max_new)

    sched.submit(make_req(0), 0)
    fake = np.ones(users, np.int32)
    submitted = 1
    peak_blocks = 0
    peak_active = 0
    useful = 0
    steps = 0
    while sched.has_work:
        sched.retire()
        sched.admit()
        # Later users arrive once user 0 finished prefilling the video —
        # the steady state of a deployed video-QA service.
        if submitted < users and any(
                st.req_id == 0 and st.cursor >= len(st.req.prompt)
                for st in sched.active.values()):
            for u in range(1, users):
                sched.submit(make_req(u), u)
            submitted = users
            sched.admit()
        if not sched.active:
            break
        plan = sched.plan()
        if plan is None:
            continue
        sched.commit(plan, fake)
        useful += int(plan.lengths.sum())
        steps += 1
        peak_blocks = max(peak_blocks, pool.live_blocks)
        peak_active = max(peak_active, len(sched.active))
    prefix_hits = sum(st.prefix_hit for st in sched.finished)
    return dict(peak_live_blocks=peak_blocks, peak_concurrent=peak_active,
                useful_tokens=useful, steps=steps, max_len=max_len,
                num_blocks=num_blocks, prefix_hit_tokens=prefix_hits)


def _contiguous_stage_tokens(*, users, video_tokens, question_tokens,
                             max_new) -> int:
    """Closed-form useful-token total of the contiguous engine on the same
    workload: every user prefills the full prompt and runs max_new - 1
    decode writes (the final sampled token is returned, never written)."""
    return users * (video_tokens + question_tokens + max_new - 1)


def _paper_stage_row(*, users=STAGE_USERS, video_tokens=STAGE_VIDEO_TOKENS,
                     question_tokens=STAGE_QUESTION_TOKENS,
                     max_new=STAGE_MAX_NEW, chunk=STAGE_CHUNK,
                     block_size=STAGE_BLOCK) -> dict:
    from repro.configs import get_config
    cfg = get_config("lwm-7b")           # full-scale cache dims
    bpt = _bytes_per_token(cfg)

    replay = _stage_replay(users=users, video_tokens=video_tokens,
                           question_tokens=question_tokens, max_new=max_new,
                           chunk=chunk, block_size=block_size)
    # The paged replay skips shared-prefix prefill compute; token parity is
    # over *content* tokens: replayed useful + registry-hit tokens must
    # equal the contiguous engine's full prefill + decode total.
    cont_tokens = _contiguous_stage_tokens(
        users=users, video_tokens=video_tokens,
        question_tokens=question_tokens, max_new=max_new)
    paged_tokens = replay["useful_tokens"] + replay["prefix_hit_tokens"]

    concurrent = replay["peak_concurrent"]
    cont_per_req = replay["max_len"] * bpt   # per-slot reservation
    paged_bytes = replay["peak_live_blocks"] * block_size * bpt
    paged_per_req = paged_bytes // max(concurrent, 1)
    return {
        "bench": "serve_paged",
        "analytic_paper_stage": {
            "workload": {"users": users, "video_tokens": video_tokens,
                         "question_tokens": question_tokens,
                         "max_new": max_new, "prefill_chunk": chunk,
                         "block_size": block_size, "model": cfg.name,
                         "kv_bytes_per_token": bpt},
            "replay": {k: int(v) for k, v in replay.items()},
            "contiguous": {"resident_kv_bytes_per_request": cont_per_req,
                           "useful_tokens": cont_tokens},
            "paged": {"resident_kv_bytes": paged_bytes,
                      "resident_kv_bytes_per_request": paged_per_req,
                      "useful_tokens": paged_tokens},
            "delta": {
                "tokens_match": paged_tokens == cont_tokens,
                "paged_strictly_fewer_resident_bytes":
                    paged_per_req < cont_per_req,
                "bytes_per_request_reduction": round(
                    cont_per_req / max(paged_per_req, 1), 2),
            },
        },
    }


def _dry_run_trace() -> None:
    """Shape-level trace of the paged prefill step (no compile/execute)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import decoding
    from repro.models.registry import build_model

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    nb = NUM_SLOTS * (MAX_LEN // BLOCK_SIZE)
    caches = jax.eval_shape(
        functools.partial(decoding.init_paged_caches, cfg, nb, BLOCK_SIZE))
    jax.eval_shape(
        functools.partial(decoding.prefill_step, cfg),
        params,
        jax.ShapeDtypeStruct((NUM_SLOTS, CHUNK), jnp.int32),
        caches,
        jax.ShapeDtypeStruct((NUM_SLOTS,), jnp.int32),
        jax.ShapeDtypeStruct((NUM_SLOTS,), jnp.int32),
        block_tables=jax.ShapeDtypeStruct((NUM_SLOTS, MAX_LEN // BLOCK_SIZE),
                                          jnp.int32))


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    if dry_run:
        _dry_run_trace()
        # Scaled-down replay: same code path, CI-smoke sized.
        return [{
            "bench": "serve_paged", "dry_run": True,
            **_paper_stage_row(users=4, video_tokens=1 << 12,
                               question_tokens=64, max_new=16, chunk=256,
                               block_size=32),
        }]
    rows = [_measured_row(), _paper_stage_row()]
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
