"""Static-vs-continuous batching accounting (paper §5 serving at scale).

Two engines serve the same mixed short/long workload:

  * "static"     — ``ServeEngine.generate_static``: the whole batch prefills
                   together (every prompt right-pads to the longest) and
                   decodes in lockstep until the *slowest* request finishes.
  * "continuous" — ``ServeEngine.serve``: a fixed slot pool; finished
                   requests retire, queued requests admit mid-flight, and
                   long prompts chunk-prefill interleaved with decode.

The unit of accounting is the *token step* (one batch row x one scan column
of model work). A token step is useful when the row actually consumed a
prompt or decode token; it is wasted when the row computed masked padding —
prompt right-padding, a finished request still stepping in lockstep, an
idle slot, or the pad tail of a prefill chunk. Continuous batching must
show strictly fewer wasted token steps (and higher tokens/step) than the
static engine; ``tools/check_bench.py`` gates the committed JSON on
exactly that, plus greedy token-level parity between the two engines.

The measured rows run the reduced LWM at small scale; the 1M-context row is
analytic — the *same* ``Scheduler`` replays the admission policy against a
bookkeeping-only ``CachePool`` (no model, no arrays), and the static side
uses the same closed-form loop the engine executes. ``--dry-run``
(CI smoke) runs the simulators plus a shape-level trace of the chunked
prefill step — no compile, no execute, no JSON write.
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

HERE = os.path.dirname(__file__)
OUT_PATH = os.path.join(HERE, "..", "BENCH_serve_batching.json")

NUM_SLOTS = 3
CHUNK = 8
MAX_LEN = 96
# (prompt_len, max_new): a short-dominated mix with a few long prompts — the
# shape that starves a lockstep batch (everything pads to 64, everything
# waits for the 8-token decoder, and request count is fixed at batch width).
WORKLOAD = [(64, 4), (48, 6), (5, 8), (4, 6), (6, 2), (5, 7),
            (8, 3), (4, 8), (6, 5), (5, 2), (7, 6), (40, 2)]
QUICK_WORKLOAD = WORKLOAD[:6]

# Paper-stage analytic workload: one slot pool serving a 1M-token context
# alongside ordinary chat-scale traffic (prompt_len, max_new).
STAGE_SLOTS = 2
STAGE_CHUNK = 4096
STAGE_WORKLOAD = [(1_048_576, 256), (131_072, 256), (32_768, 128),
                  (8_192, 128), (524_288, 256), (16_384, 64)]


# ---------------------------------------------------------------------------
# Analytic simulators (host-only; no model, no device arrays)
# ---------------------------------------------------------------------------

def simulate_continuous(workload, *, num_slots, chunk, max_len) -> dict:
    """Replay the REAL scheduler (bookkeeping-only CachePool) over a
    workload of (prompt_len, max_new) pairs and count token steps."""
    from repro.serve import CachePool, Request, Scheduler

    pool = CachePool(num_slots, max_len=max_len)
    sched = Scheduler(pool, prefill_chunk=chunk, vocab_size=2)
    for i, (p, g) in enumerate(workload):
        sched.submit(Request(prompt=np.zeros(p, np.int32), max_new_tokens=g),
                     i)
    fake = np.ones(num_slots, np.int32)     # token 1; no request sets eos
    stats = dict(engine="continuous", num_slots=num_slots,
                 prefill_chunk=chunk, model_calls=0, scan_columns=0,
                 token_slots=0, useful_tokens=0)
    while True:
        sched.retire()
        sched.admit()
        if not sched.active:
            break
        plan = sched.plan()
        sched.commit(plan, fake)
        stats["model_calls"] += 1
        stats["scan_columns"] += plan.columns
        stats["token_slots"] += int(plan.tokens.size)
        stats["useful_tokens"] += int(plan.lengths.sum())
    return _finish(stats)


def simulate_static(workload) -> dict:
    """Closed-form mirror of ``generate_static``'s accounting loop."""
    n = len(workload)
    lens = [p for p, _ in workload]
    gens = [g for _, g in workload]
    s_max, g_max = max(lens), max(gens)
    stats = dict(engine="static", batch=n, model_calls=1,
                 scan_columns=s_max, token_slots=n * s_max,
                 useful_tokens=sum(lens))
    counts = [0] * n
    done = [False] * n
    for t in range(g_max):
        for i in range(n):
            if not done[i]:
                counts[i] += 1
            if counts[i] >= gens[i]:
                done[i] = True
        if all(done) or t == g_max - 1:
            break
        stats["model_calls"] += 1
        stats["scan_columns"] += 1
        stats["token_slots"] += n
        stats["useful_tokens"] += sum(1 for d in done if not d)
    return _finish(stats)


def _finish(stats: dict) -> dict:
    from repro.serve.engine import _finish_stats
    return _finish_stats(stats)


def _delta(static: dict, continuous: dict, tokens_match=None) -> dict:
    d = {
        "wasted_pad_steps_saved": (static["wasted_token_steps"]
                                   - continuous["wasted_token_steps"]),
        "continuous_strictly_fewer_wasted": (
            continuous["wasted_token_steps"] < static["wasted_token_steps"]),
        "waste_reduction": round(
            static["wasted_token_steps"]
            / max(continuous["wasted_token_steps"], 1), 2),
        "utilization_gain": round(
            continuous["utilization"] / max(static["utilization"], 1e-9), 3),
    }
    if tokens_match is not None:
        d["tokens_match"] = tokens_match
    return d


# ---------------------------------------------------------------------------
# Measured small-scale run
# ---------------------------------------------------------------------------

def _requests(workload):
    from repro.serve import Request
    return [Request(prompt=(7 + np.arange(p, dtype=np.int32) * 3) % 900,
                    max_new_tokens=g)
            for p, g in workload]


def _measured_row(workload) -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.models.registry import build_model
    from repro.serve import CacheConfig, ServeConfig, ServeEngine

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      ServeConfig(cache=CacheConfig(max_len=MAX_LEN)))

    t0 = time.time()
    static_res = eng.generate_static(_requests(workload))
    static = dict(eng.stats, wall_s=round(time.time() - t0, 2))
    t0 = time.time()
    cont_res = eng.serve(_requests(workload), num_slots=NUM_SLOTS,
                         prefill_chunk=CHUNK)
    cont = dict(eng.stats, wall_s=round(time.time() - t0, 2))
    tokens_match = all(
        np.array_equal(s.tokens, c.tokens)
        for s, c in zip(static_res, cont_res))
    return {
        "bench": "serve_batching",
        "backend": jax.default_backend(),
        "workload": {"requests": len(workload),
                     "prompt_lens": [p for p, _ in workload],
                     "max_new": [g for _, g in workload],
                     "num_slots": NUM_SLOTS, "prefill_chunk": CHUNK,
                     "max_len": MAX_LEN, "model": cfg.name},
        "static": static,
        "continuous": cont,
        "delta": _delta(static, cont, tokens_match=tokens_match),
    }


def _paper_stage_row() -> dict:
    static = simulate_static(STAGE_WORKLOAD)
    cont = simulate_continuous(STAGE_WORKLOAD, num_slots=STAGE_SLOTS,
                               chunk=STAGE_CHUNK, max_len=2 ** 21)
    return {
        "bench": "serve_batching",
        "analytic_paper_stage": {
            "workload": {"prompt_lens": [p for p, _ in STAGE_WORKLOAD],
                         "max_new": [g for _, g in STAGE_WORKLOAD],
                         "num_slots": STAGE_SLOTS,
                         "prefill_chunk": STAGE_CHUNK},
            "static": static,
            "continuous": cont,
            "delta": _delta(static, cont),
        },
    }


def _dry_run_trace() -> None:
    """Shape-level trace of the chunked prefill step (no compile/execute)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import decoding
    from repro.models.registry import build_model

    cfg = get_reduced("lwm-7b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    caches = jax.eval_shape(
        functools.partial(decoding.init_caches, cfg, NUM_SLOTS, MAX_LEN))
    jax.eval_shape(
        functools.partial(decoding.prefill_step, cfg),
        params,
        jax.ShapeDtypeStruct((NUM_SLOTS, CHUNK), jnp.int32),
        caches,
        jax.ShapeDtypeStruct((NUM_SLOTS,), jnp.int32),
        jax.ShapeDtypeStruct((NUM_SLOTS,), jnp.int32))


def run(*, quick: bool = False, dry_run: bool = False) -> list[dict]:
    workload = QUICK_WORKLOAD if quick else WORKLOAD
    if dry_run:
        _dry_run_trace()
        static = simulate_static(workload)
        cont = simulate_continuous(workload, num_slots=NUM_SLOTS,
                                   chunk=CHUNK, max_len=MAX_LEN)
        rows = [{
            "bench": "serve_batching", "dry_run": True,
            "static": static, "continuous": cont,
            "delta": _delta(static, cont),
        }, _paper_stage_row()]
        return rows

    rows = [_measured_row(workload), _paper_stage_row()]
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, dry_run=args.dry_run):
        print(json.dumps(row, indent=2))


if __name__ == "__main__":
    main()
