"""Paper Figures 2/5 (single needle) + Figure 6 / Table 3 (multi-needle).

Fine-tunes a reduced model on the synthetic needle-retrieval grammar, then
evaluates over a (context depth x context length) grid — the structure of
the paper's needle plots — plus the multi-needle (N, R) matrix.

Metrics: exact argmax accuracy (the paper's), top-8 accuracy, and
"retrieval lift" = answer-token log-prob above the filler-unigram baseline.
A 2-layer reduced model needs thousands of steps to grow full induction
heads on one CPU core, so quick mode primarily demonstrates lift/top-8;
--full pushes exact accuracy up (the code path is scale-free — the paper's
7B model at 1M context is the same computation).

``serve_retrieval`` additionally runs retrieval through the REAL
``ServeEngine`` (prompt = context up to the answer, greedy generation of
the value) so recall can be compared across cache pools — the accuracy
gate for int8 KV-cache quantization (``benchmarks/serve_quant.py``).

For that gate the model is not trained at all: a 2-layer reduced model on
one CPU core never completes the induction phase transition in a bench
budget (loss plateaus at the value-band unigram marginal), so
``programmed_retrieval_model`` instead CONSTRUCTS the retrieval circuit by
hand — a fixed-offset RoPE addressing head (multi-frequency phase match on
the rotating dims) whose OV path copies the needle value's orthogonal
embedding code into a dedicated logit band. Recall through the f32 engine
is 1.0 by construction; a quantized cache must preserve both the attention
addressing (K fidelity) and the copied value code (V fidelity) through the
real split-K decode kernels to keep it there, which is exactly what the
gate needs to measure.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.needle import VALUE_BAND, NeedleTask, retrieval_accuracy
from repro.data.vocab import build_vocab
from repro.models.registry import build_model
from repro.train.train_step import init_train_state, make_eval_step, make_train_step


def topk_accuracy(logits: np.ndarray, batch: dict, k: int = 8) -> float:
    slots = batch["answer_slots"]
    vals = batch["answer_values"]
    b_idx = np.arange(slots.shape[0])[:, None, None]
    at = logits[b_idx, slots - 1]                       # (B, R, V, vocab)
    kth = np.sort(at, axis=-1)[..., -k][..., None]
    hit = np.take_along_axis(at, vals[..., None], axis=-1)[..., 0] >= kth[..., 0]
    return float(np.mean(np.all(hit, axis=-1)))


def answer_logprob(logits: np.ndarray, batch: dict) -> float:
    slots = batch["answer_slots"]
    vals = batch["answer_values"]
    b_idx = np.arange(slots.shape[0])[:, None, None]
    at = logits[b_idx, slots - 1]
    lse = np.log(np.exp(at - at.max(-1, keepdims=True)).sum(-1)) + at.max(-1)
    lp = np.take_along_axis(at, vals[..., None], axis=-1)[..., 0] - lse
    return float(np.mean(lp))


def _train_batch(nt, rows, seq, rng, max_needles=4):
    n = int(rng.integers(1, max_needles + 1))
    r = int(rng.integers(1, n + 1))
    b = nt.batch(rows, seq, num_needles=n, num_retrieve=r)
    return {
        "tokens": b["tokens"],
        "labels": np.roll(b["tokens"], -1, axis=1),
        "segment_ids": np.ones_like(b["tokens"]),
        "positions": np.tile(np.arange(seq, dtype=np.int32), (rows, 1)),
        "loss_weights": np.roll(b["loss_mask"], -1, axis=1).astype(np.float32),
    }


def train_retrieval_model(*, train_steps: int = 250, seq: int = 128,
                          rows: int = 8) -> dict:
    """Train the reduced LWM on the (1, 1) pure-induction needle grammar.

    Shared by ``run`` below and by ``benchmarks/serve_quant.py`` (which
    serves the trained model through quantized vs f32 cache pools as its
    recall gate). Returns the pieces both callers need: the config, the
    trained state, the task, the jitted eval step, the final train loss,
    and the *untrained* answer log-prob baseline for the lift metric.
    """
    cfg = get_reduced("lwm-7b")
    vocab = build_vocab(cfg.vocab_size, 0)
    nt = NeedleTask(vocab, seed=0, key_len=1, val_len=1)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, learning_rate=3e-3, weight_decay=0.0))
    eval_step = jax.jit(make_eval_step(cfg))
    rng = np.random.default_rng(0)

    # baseline (untrained) answer log-prob for the lift metric
    b0 = nt.batch(rows, seq, num_needles=1, num_retrieve=1)
    lg0, _ = eval_step(state.params, _eval_batch(b0, rows, seq))
    base_lp = answer_logprob(np.asarray(lg0, np.float32), b0)

    loss = None
    for _ in range(train_steps):
        state, m = step(state, _train_batch(nt, rows, seq, rng))
        loss = float(m["loss"])
    return dict(cfg=cfg, state=state, task=nt, eval_step=eval_step,
                final_loss=loss, baseline_logprob=base_lp)


def programmed_retrieval_model(*, seq: int = 128, depth: float = 0.2) -> dict:
    """Reduced LWM whose weights are CONSTRUCTED (not trained) to retrieve
    the (1, 1) needle at a fixed depth — the deterministic recall probe for
    the int8 KV-cache gate (``benchmarks/serve_quant.py``).

    Circuit (layer 1 of 2; layer 0 and both MLPs are zeroed no-ops):

      * Every token embedding is unit-norm with a shared component ``BETA``
        on one residual dim; value-band tokens additionally carry an
        orthogonal ``+/-e_j`` identity code in dims 0..63.
      * Head 0's q/k read only the shared component, placed on the first
        ``NPAIRS`` RoPE dim pairs with per-pair query phase ``-f_i * O``
        (O = answer position - value position, a constant of the fixed
        layout). Post-rotation logits are ``sum_i cos(f_i (s - O))`` at
        relative distance s — a multi-frequency comb peaked exactly at the
        needle value, with incommensurate frequencies suppressing aliases.
      * The OV path copies the attended identity code into a dedicated
        output band that only value-token lm_head columns read, so the
        argmax IS the hidden value and every other logit is exactly 0.

    Greedy recall through the f32 engine is 1.0 by construction; an int8
    cache must preserve K (addressing) and V (copied code) through the
    real split-K decode kernels to match it. Returns cfg/params/task plus
    the layout constants and the attention-comb margin."""
    cfg = get_reduced("lwm-7b")
    vocab = build_vocab(cfg.vocab_size, 0)
    task = NeedleTask(vocab, seed=0, key_len=1, val_len=1)

    # Fixed layout (must mirror NeedleTask.build): needle sentence is
    # marker(2)+key+val+sep at start = depth*(body-5); the tail is
    # query_marker(2)+key+val, so the engine's generating position P is the
    # query key and the value sits O positions behind it.
    body_len = seq - 4
    start = int(depth * (body_len - 5))
    val_pos = start + 3
    gen_pos = body_len + 2
    offset = gen_pos - val_pos

    d, hd, vsz = cfg.d_model, cfg.resolved_head_dim, cfg.vocab_size
    beta = 0.25                      # shared-direction coefficient
    val_lo, val_hi = VALUE_BAND
    nval = val_hi - val_lo
    npairs = 6
    gamma = 14.0                     # q/k magnitude per rotating pair

    inv_freq = 1.0 / cfg.rope_theta ** (np.arange(0, hd, 2) / hd)
    s_axis = np.arange(0, gen_pos + 1)
    comb = sum(np.cos(inv_freq[i] * (s_axis - offset)) for i in range(npairs))
    margin = float(comb[offset] - np.sort(comb)[-2])
    assert int(np.argmax(comb)) == offset and margin > 0.4, \
        f"addressing comb not peaked at the needle (margin={margin:.3f})"

    rng = np.random.default_rng(0)
    embed = np.zeros((vsz, d), np.float32)
    junk = rng.normal(size=(vsz, 64)).astype(np.float32)
    junk /= np.linalg.norm(junk, axis=1, keepdims=True)
    embed[:, 128:192] = junk * np.sqrt(1 - beta ** 2)   # norm filler, unread
    for j in range(nval):
        embed[val_lo + j, 128:192] = 0.0
        embed[val_lo + j, j % 64] = np.sqrt(1 - beta ** 2) * (1 - 2 * (j >= 64))
    embed[:, 64] = beta

    params = build_model(cfg).init(jax.random.PRNGKey(0))
    lay = params["layers_0_attn_dense"]
    wq, wk, wv, wo = (np.zeros((2, d, d), np.float32) for _ in range(4))
    hc = beta * np.sqrt(d)           # shared component after RMSNorm
    for i in range(npairs):
        wk[1, 64, i] = gamma / hc
        wq[1, 64, i] = gamma * np.cos(-inv_freq[i] * offset) / hc
        wq[1, 64, 32 + i] = gamma * np.sin(-inv_freq[i] * offset) / hc
    for i in range(64):
        wv[1, i, i] = 1.0            # identity band -> head-0 values
        wo[1, i, 192 + i] = 1.0      # head-0 values -> output band
    lay["attn"].update(wq=jnp.asarray(wq), wk=jnp.asarray(wk),
                       wv=jnp.asarray(wv), wo=jnp.asarray(wo))
    lay["ln1"] = jnp.ones((2, d), jnp.float32)
    lay["ln2"] = jnp.ones((2, d), jnp.float32)
    for name in lay["mlp"]:
        lay["mlp"][name] = jnp.zeros_like(lay["mlp"][name])
    params["embed"] = jnp.asarray(embed)
    params["final_norm"] = jnp.ones((d,), jnp.float32)
    lm = np.zeros((d, vsz), np.float32)
    for j in range(nval):
        lm[192 + (j % 64), val_lo + j] = 1 - 2 * (j >= 64)
    params["lm_head"] = jnp.asarray(lm)
    return dict(cfg=cfg, params=params, task=task, depth=depth, seq=seq,
                offset=offset, margin=round(margin, 3))


def serve_retrieval(cfg, params, task, *, seq: int, cache=None,
                    decode_impl=None, rows: int = 8, batches: int = 4,
                    num_slots: int = 4, prefill_chunk: int = 16,
                    depth: float | None = None) -> float:
    """Needle recall through the REAL ``ServeEngine`` (not teacher-forced
    eval): each example's context up to the answer becomes a prompt, the
    engine generates the value greedily, recall = fraction of retrievals
    whose generated tokens equal the hidden value exactly. ``cache``
    selects the pool under test (contiguous/paged, f32/int8) — this is the
    recall gate for KV-cache quantization (``tools/check_bench.py``).
    ``depth`` pins the needle depth (required for the programmed
    fixed-offset model; None keeps the task's random depths)."""
    from repro.serve import CacheConfig, Request, ServeConfig, ServeEngine

    if cache is None:
        cache = CacheConfig(max_len=seq + task.val_len)
    eng = ServeEngine(cfg, params,
                      ServeConfig(cache=cache, decode_impl=decode_impl))
    depths = None if depth is None else np.array([depth])
    hits = total = 0
    for _ in range(batches):
        b = task.batch(rows, seq, num_needles=1, num_retrieve=1,
                       depths=depths)
        reqs, vals = [], []
        for i in range(rows):
            first = int(b["answer_slots"][i, 0, 0])
            reqs.append(Request(
                prompt=b["tokens"][i, :first].astype(np.int32),
                max_new_tokens=task.val_len))
            vals.append(np.asarray(b["answer_values"][i, 0], np.int32))
        res = eng.serve(reqs, num_slots=num_slots,
                        prefill_chunk=prefill_chunk)
        for r, v in zip(res, vals):
            hits += int(np.array_equal(r.tokens, v))
            total += 1
    return hits / total


def run(*, train_steps: int = 1500, seq: int = 128, rows: int = 8,
        quick: bool = False) -> list[dict]:
    if quick:
        train_steps = 250
    tr = train_retrieval_model(train_steps=train_steps, seq=seq, rows=rows)
    cfg, state, nt = tr["cfg"], tr["state"], tr["task"]
    eval_step = tr["eval_step"]
    base_lp = tr["baseline_logprob"]
    loss = tr["final_loss"]

    rows_out = []

    def evaluate(seq_len, depth, n=1, r=1, batches=4):
        accs, top8, lps = [], [], []
        for _ in range(batches):
            b = nt.batch(rows, seq_len, num_needles=n, num_retrieve=r,
                         depths=(np.full(n, depth) if n == 1 else None))
            logits, _ = eval_step(state.params, _eval_batch(b, rows, seq_len))
            lf = np.asarray(logits, np.float32)
            accs.append(retrieval_accuracy(lf, b))
            top8.append(topk_accuracy(lf, b))
            lps.append(answer_logprob(lf, b))
        return (float(np.mean(accs)), float(np.mean(top8)),
                float(np.mean(lps) - base_lp))

    # Figure 5 analogue: depth x length grid (trained length and 2x extension)
    for seq_len in (seq, 2 * seq):
        for depth in (0.1, 0.5, 0.9):
            acc, t8, lift = evaluate(seq_len, depth)
            rows_out.append({"bench": "needle", "mode": "single",
                             "seq_len": seq_len, "depth": depth,
                             "N": 1, "R": 1, "acc": round(acc, 3),
                             "top8": round(t8, 3),
                             "logprob_lift": round(lift, 3)})
    # Figure 6 / Table 3 analogue: multi-needle (N, R) matrix
    for n, r in ((2, 2), (4, 1), (4, 2)):
        acc, t8, lift = evaluate(seq, 0.5, n=n, r=r)
        rows_out.append({"bench": "needle", "mode": "multi", "seq_len": seq,
                         "depth": None, "N": n, "R": r, "acc": round(acc, 3),
                         "top8": round(t8, 3), "logprob_lift": round(lift, 3)})
    rows_out.append({"bench": "needle", "mode": "train", "seq_len": seq,
                     "depth": None, "N": None, "R": None, "acc": None,
                     "final_train_loss": round(loss, 4),
                     "baseline_answer_logprob": round(base_lp, 3)})
    # Engine-level recall: the same trained model served through the real
    # continuous-batching engine, f32 vs int8 paged pools (the quant gate's
    # code path; the committed gated numbers live in BENCH_serve_quant.json).
    from repro.serve import CacheConfig
    f32_cache = CacheConfig(max_len=seq + 8, paged=True, block_size=16)
    int8_cache = dataclasses.replace(f32_cache, quant="int8",
                                     quant_tail_blocks=1)
    for pool, cache in (("paged_f32", f32_cache), ("paged_int8", int8_cache)):
        recall = serve_retrieval(cfg, state.params, nt, seq=seq, cache=cache,
                                 rows=rows)
        rows_out.append({"bench": "needle", "mode": "serve", "pool": pool,
                         "seq_len": seq, "depth": None, "N": 1, "R": 1,
                         "acc": round(recall, 3)})
    return rows_out


def _eval_batch(b, rows, seq_len):
    return {
        "tokens": b["tokens"],
        "labels": np.roll(b["tokens"], -1, axis=1),
        "segment_ids": np.ones_like(b["tokens"]),
        "positions": np.tile(np.arange(seq_len, dtype=np.int32), (rows, 1)),
        "loss_weights": np.roll(b["loss_mask"], -1, axis=1).astype(np.float32),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args(argv)
    for row in run(train_steps=args.train_steps, seq=args.seq):
        print(row)


if __name__ == "__main__":
    main()
