"""Paper Figures 2/5 (single needle) + Figure 6 / Table 3 (multi-needle).

Fine-tunes a reduced model on the synthetic needle-retrieval grammar, then
evaluates over a (context depth x context length) grid — the structure of
the paper's needle plots — plus the multi-needle (N, R) matrix.

Metrics: exact argmax accuracy (the paper's), top-8 accuracy, and
"retrieval lift" = answer-token log-prob above the filler-unigram baseline.
A 2-layer reduced model needs thousands of steps to grow full induction
heads on one CPU core, so quick mode primarily demonstrates lift/top-8;
--full pushes exact accuracy up (the code path is scale-free — the paper's
7B model at 1M context is the same computation).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.needle import NeedleTask, retrieval_accuracy
from repro.data.vocab import build_vocab
from repro.models.registry import build_model
from repro.train.train_step import init_train_state, make_eval_step, make_train_step


def topk_accuracy(logits: np.ndarray, batch: dict, k: int = 8) -> float:
    slots = batch["answer_slots"]
    vals = batch["answer_values"]
    b_idx = np.arange(slots.shape[0])[:, None, None]
    at = logits[b_idx, slots - 1]                       # (B, R, V, vocab)
    kth = np.sort(at, axis=-1)[..., -k][..., None]
    hit = np.take_along_axis(at, vals[..., None], axis=-1)[..., 0] >= kth[..., 0]
    return float(np.mean(np.all(hit, axis=-1)))


def answer_logprob(logits: np.ndarray, batch: dict) -> float:
    slots = batch["answer_slots"]
    vals = batch["answer_values"]
    b_idx = np.arange(slots.shape[0])[:, None, None]
    at = logits[b_idx, slots - 1]
    lse = np.log(np.exp(at - at.max(-1, keepdims=True)).sum(-1)) + at.max(-1)
    lp = np.take_along_axis(at, vals[..., None], axis=-1)[..., 0] - lse
    return float(np.mean(lp))


def _train_batch(nt, rows, seq, rng, max_needles=4):
    n = int(rng.integers(1, max_needles + 1))
    r = int(rng.integers(1, n + 1))
    b = nt.batch(rows, seq, num_needles=n, num_retrieve=r)
    return {
        "tokens": b["tokens"],
        "labels": np.roll(b["tokens"], -1, axis=1),
        "segment_ids": np.ones_like(b["tokens"]),
        "positions": np.tile(np.arange(seq, dtype=np.int32), (rows, 1)),
        "loss_weights": np.roll(b["loss_mask"], -1, axis=1).astype(np.float32),
    }


def run(*, train_steps: int = 1500, seq: int = 128, rows: int = 8,
        quick: bool = False) -> list[dict]:
    if quick:
        train_steps = 250
    cfg = get_reduced("lwm-7b")
    vocab = build_vocab(cfg.vocab_size, 0)
    nt = NeedleTask(vocab, seed=0, key_len=1, val_len=1)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, learning_rate=3e-3, weight_decay=0.0))
    eval_step = jax.jit(make_eval_step(cfg))
    rng = np.random.default_rng(0)

    # baseline (untrained) answer log-prob for the lift metric
    b0 = nt.batch(rows, seq, num_needles=1, num_retrieve=1)
    eb0 = _eval_batch(b0, rows, seq)
    lg0, _ = eval_step(state.params, eb0)
    base_lp = answer_logprob(np.asarray(lg0, np.float32), b0)

    loss = None
    for i in range(train_steps):
        state, m = step(state, _train_batch(nt, rows, seq, rng))
        loss = float(m["loss"])

    rows_out = []

    def evaluate(seq_len, depth, n=1, r=1, batches=4):
        accs, top8, lps = [], [], []
        for _ in range(batches):
            b = nt.batch(rows, seq_len, num_needles=n, num_retrieve=r,
                         depths=(np.full(n, depth) if n == 1 else None))
            logits, _ = eval_step(state.params, _eval_batch(b, rows, seq_len))
            lf = np.asarray(logits, np.float32)
            accs.append(retrieval_accuracy(lf, b))
            top8.append(topk_accuracy(lf, b))
            lps.append(answer_logprob(lf, b))
        return (float(np.mean(accs)), float(np.mean(top8)),
                float(np.mean(lps) - base_lp))

    # Figure 5 analogue: depth x length grid (trained length and 2x extension)
    for seq_len in (seq, 2 * seq):
        for depth in (0.1, 0.5, 0.9):
            acc, t8, lift = evaluate(seq_len, depth)
            rows_out.append({"bench": "needle", "mode": "single",
                             "seq_len": seq_len, "depth": depth,
                             "N": 1, "R": 1, "acc": round(acc, 3),
                             "top8": round(t8, 3),
                             "logprob_lift": round(lift, 3)})
    # Figure 6 / Table 3 analogue: multi-needle (N, R) matrix
    for n, r in ((2, 2), (4, 1), (4, 2)):
        acc, t8, lift = evaluate(seq, 0.5, n=n, r=r)
        rows_out.append({"bench": "needle", "mode": "multi", "seq_len": seq,
                         "depth": None, "N": n, "R": r, "acc": round(acc, 3),
                         "top8": round(t8, 3), "logprob_lift": round(lift, 3)})
    rows_out.append({"bench": "needle", "mode": "train", "seq_len": seq,
                     "depth": None, "N": None, "R": None, "acc": None,
                     "final_train_loss": round(loss, 4),
                     "baseline_answer_logprob": round(base_lp, 3)})
    return rows_out


def _eval_batch(b, rows, seq_len):
    return {
        "tokens": b["tokens"],
        "labels": np.roll(b["tokens"], -1, axis=1),
        "segment_ids": np.ones_like(b["tokens"]),
        "positions": np.tile(np.arange(seq_len, dtype=np.int32), (rows, 1)),
        "loss_weights": np.roll(b["loss_mask"], -1, axis=1).astype(np.float32),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args(argv)
    for row in run(train_steps=args.train_steps, seq=args.seq):
        print(row)


if __name__ == "__main__":
    main()
